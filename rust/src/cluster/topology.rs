//! Cluster topology: how a global vocabulary is split across shards, how a
//! global id maps to a (shard, local id) pair, and which network addresses
//! serve each shard.
//!
//! Parsed from a `[cluster]` TOML section (standalone topology file or a
//! section of the experiment config):
//!
//! ```toml
//! [cluster]
//! vocab = 118655            # global vocabulary size
//! strategy = "range"        # "range" (contiguous slices) | "hash"
//! shard0 = ["10.0.0.1:7878", "10.0.1.1:7878"]   # replicas of shard 0
//! shard1 = ["10.0.0.2:7878", "10.0.1.2:7878"]
//! ```
//!
//! Both strategies are O(1) invertible in each direction, so the router
//! maps global→local without per-id tables and a shard maps local→global
//! when reporting results:
//!
//! * **range** — shard `i` owns the contiguous slice `[start_i, end_i)`
//!   with sizes balanced to within one id; `local = global − start`.
//!   Preserves id order inside a shard (tie-breaking stays globally
//!   consistent for free) and makes shard files contiguous row slices.
//! * **hash** — `shard = global mod n`, `local = global ÷ n`. Interleaves
//!   the vocabulary so the Zipf head (low ids in frequency-sorted vocabs)
//!   spreads across all shards instead of hammering shard 0.

use crate::config::{TomlDoc, TomlValue};
use crate::error::{Error, Result};
use crate::snapshot::{ShardRange, SHARD_STRATEGY_HASH, SHARD_STRATEGY_RANGE};
use std::path::Path;

/// How global ids are assigned to shards (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStrategy {
    Range,
    Hash,
}

impl ShardStrategy {
    pub fn parse(s: &str) -> Result<ShardStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "range" => Ok(ShardStrategy::Range),
            "hash" | "mod" | "interleave" => Ok(ShardStrategy::Hash),
            other => Err(Error::Config(format!(
                "unknown shard strategy '{other}' (expected range|hash)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardStrategy::Range => "range",
            ShardStrategy::Hash => "hash",
        }
    }

    /// Snapshot-section tag (see [`crate::snapshot::ShardRange`]).
    pub fn tag(&self) -> u32 {
        match self {
            ShardStrategy::Range => SHARD_STRATEGY_RANGE,
            ShardStrategy::Hash => SHARD_STRATEGY_HASH,
        }
    }
}

/// A validated cluster topology: vocabulary split + replica addresses.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    vocab: usize,
    strategy: ShardStrategy,
    /// `addrs[shard]` is that shard's replica group, in failover order.
    addrs: Vec<Vec<String>>,
}

impl Topology {
    /// Build and validate: at least one shard, every shard at least one
    /// replica, and no more shards than vocabulary entries (an id-less
    /// shard could never answer anything).
    pub fn new(
        vocab: usize,
        strategy: ShardStrategy,
        addrs: Vec<Vec<String>>,
    ) -> Result<Topology> {
        if vocab == 0 {
            return Err(Error::Config("cluster vocab must be >= 1".into()));
        }
        if addrs.is_empty() {
            return Err(Error::Config("cluster needs at least one shard".into()));
        }
        if addrs.len() > vocab {
            return Err(Error::Config(format!(
                "{} shards over a {vocab}-word vocabulary leaves empty shards",
                addrs.len()
            )));
        }
        for (s, group) in addrs.iter().enumerate() {
            if group.is_empty() {
                return Err(Error::Config(format!("shard {s} has no replicas")));
            }
        }
        Ok(Topology { vocab, strategy, addrs })
    }

    /// Parse the `[cluster]` section of a parsed TOML document.
    pub fn from_doc(doc: &TomlDoc) -> Result<Topology> {
        let vocab = doc
            .get("cluster.vocab")
            .and_then(TomlValue::as_usize)
            .ok_or_else(|| Error::Config("[cluster] needs vocab = <global size>".into()))?;
        let strategy = match doc.get("cluster.strategy") {
            Some(v) => ShardStrategy::parse(v.as_str().unwrap_or(""))?,
            None => ShardStrategy::Range,
        };
        let mut addrs = Vec::new();
        loop {
            let key = format!("cluster.shard{}", addrs.len());
            let Some(v) = doc.get(&key) else { break };
            let group = match v {
                TomlValue::Arr(items) => items
                    .iter()
                    .map(|it| {
                        it.as_str().map(str::to_string).ok_or_else(|| {
                            Error::Config(format!("{key}: replicas must be strings"))
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
                // A single replica may be written without brackets.
                TomlValue::Str(s) => vec![s.clone()],
                _ => {
                    return Err(Error::Config(format!(
                        "{key} must be an array of \"host:port\" strings"
                    )))
                }
            };
            addrs.push(group);
        }
        if addrs.is_empty() {
            return Err(Error::Config(
                "[cluster] needs shard0 = [\"host:port\", ...] (contiguously numbered)".into(),
            ));
        }
        // Enforce contiguity: `shard0` + `shard2` silently parsing as a
        // one-shard cluster would route ids against snapshots cut for a
        // different split — wrong rows with status OK.
        for key in doc.keys() {
            if let Some(suffix) = key.strip_prefix("cluster.shard") {
                if let Ok(i) = suffix.parse::<usize>() {
                    if i >= addrs.len() {
                        return Err(Error::Config(format!(
                            "[cluster] shard keys must be contiguous from shard0: found \
                             shard{i} but shard{} is missing",
                            addrs.len()
                        )));
                    }
                }
            }
        }
        Topology::new(vocab, strategy, addrs)
    }

    /// Parse a topology TOML source (must contain a `[cluster]` section).
    pub fn parse(src: &str) -> Result<Topology> {
        Topology::from_doc(&TomlDoc::parse(src)?)
    }

    /// Load a topology file.
    pub fn load(path: &Path) -> Result<Topology> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        Topology::parse(&src)
    }

    /// The same split with replacement replica addresses (self-hosted
    /// demos/benches that spawn shard servers on OS-assigned ports).
    pub fn with_addrs(&self, addrs: Vec<Vec<String>>) -> Result<Topology> {
        if addrs.len() != self.addrs.len() {
            return Err(Error::Config(format!(
                "replacement addresses describe {} shards, topology has {}",
                addrs.len(),
                self.addrs.len()
            )));
        }
        Topology::new(self.vocab, self.strategy, addrs)
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn strategy(&self) -> ShardStrategy {
        self.strategy
    }

    pub fn n_shards(&self) -> usize {
        self.addrs.len()
    }

    /// Replica addresses of one shard, in failover order.
    pub fn replicas(&self, shard: usize) -> &[String] {
        &self.addrs[shard]
    }

    /// Total replica count across all shards.
    pub fn n_replicas(&self) -> usize {
        self.addrs.iter().map(Vec::len).sum()
    }

    /// Balanced range split: (start, length) of shard `s` under the range
    /// strategy. The first `vocab % n` shards get one extra id.
    fn range_of(&self, s: usize) -> (usize, usize) {
        let n = self.addrs.len();
        let (base, rem) = (self.vocab / n, self.vocab % n);
        let start = s * base + s.min(rem);
        (start, base + usize::from(s < rem))
    }

    /// Map a global id to its owning shard and shard-local id. Panics if
    /// `global >= vocab` (callers validate at the request boundary).
    pub fn locate(&self, global: usize) -> (usize, usize) {
        assert!(global < self.vocab, "global id {global} outside vocab {}", self.vocab);
        let n = self.addrs.len();
        match self.strategy {
            ShardStrategy::Hash => (global % n, global / n),
            ShardStrategy::Range => {
                let (base, rem) = (self.vocab / n, self.vocab % n);
                let big = rem * (base + 1);
                if global < big {
                    (global / (base + 1), global % (base + 1))
                } else {
                    // base > 0 here: rem == n would put every id in `big`.
                    let rest = global - big;
                    (rem + rest / base, rest % base)
                }
            }
        }
    }

    /// Inverse of [`locate`](Self::locate).
    pub fn global_id(&self, shard: usize, local: usize) -> usize {
        match self.strategy {
            ShardStrategy::Hash => local * self.addrs.len() + shard,
            ShardStrategy::Range => self.range_of(shard).0 + local,
        }
    }

    /// How many global ids shard `s` owns.
    pub fn local_count(&self, s: usize) -> usize {
        match self.strategy {
            ShardStrategy::Range => self.range_of(s).1,
            ShardStrategy::Hash => {
                let n = self.addrs.len();
                (self.vocab - s).div_ceil(n)
            }
        }
    }

    /// Global ids owned by shard `s`, in local-id order.
    pub fn shard_ids(&self, s: usize) -> impl Iterator<Item = usize> + '_ {
        (0..self.local_count(s)).map(move |local| self.global_id(s, local))
    }

    /// The snapshot-manifest form of shard `s`'s assignment
    /// ([`crate::snapshot::SaveOptions::shard_range`]).
    pub fn shard_range(&self, s: usize) -> ShardRange {
        let (start, len) = match self.strategy {
            ShardStrategy::Range => self.range_of(s),
            ShardStrategy::Hash => (0, 0),
        };
        ShardRange {
            strategy: self.strategy.tag(),
            shard: s as u32,
            n_shards: self.addrs.len() as u32,
            global_vocab: self.vocab as u64,
            start: start as u64,
            end: match self.strategy {
                ShardStrategy::Range => (start + len) as u64,
                ShardStrategy::Hash => 0,
            },
        }
    }

    /// Render back to `[cluster]` TOML (demos that spawn their own shard
    /// servers persist the effective topology for the operator).
    pub fn to_toml(&self) -> String {
        let mut s = format!(
            "[cluster]\nvocab = {}\nstrategy = \"{}\"\n",
            self.vocab,
            self.strategy.name()
        );
        for (i, group) in self.addrs.iter().enumerate() {
            let quoted: Vec<String> = group.iter().map(|a| format!("\"{a}\"")).collect();
            s.push_str(&format!("shard{i} = [{}]\n", quoted.join(", ")));
        }
        s
    }

    pub fn describe(&self) -> String {
        format!(
            "{} shards × up to {} replicas, {} sharding over {} words",
            self.addrs.len(),
            self.addrs.iter().map(Vec::len).max().unwrap_or(0),
            self.strategy.name(),
            self.vocab
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(vocab: usize, strategy: ShardStrategy, shards: usize) -> Topology {
        let addrs = (0..shards).map(|s| vec![format!("127.0.0.1:{}", 7000 + s)]).collect();
        Topology::new(vocab, strategy, addrs).unwrap()
    }

    #[test]
    fn locate_and_global_id_are_inverse_for_both_strategies() {
        for strategy in [ShardStrategy::Range, ShardStrategy::Hash] {
            for (vocab, shards) in [(10, 3), (100, 4), (7, 7), (101, 2), (1, 1)] {
                let t = topo(vocab, strategy, shards);
                let mut seen = vec![false; vocab];
                for g in 0..vocab {
                    let (s, l) = t.locate(g);
                    assert!(s < shards, "{strategy:?} {vocab}/{shards}: shard {s}");
                    assert!(l < t.local_count(s), "{strategy:?}: local {l} out of range");
                    assert_eq!(t.global_id(s, l), g, "{strategy:?} {vocab}/{shards}");
                    assert!(!seen[g]);
                    seen[g] = true;
                }
                // Every shard's count adds up and shard_ids enumerates its
                // exact slice in local order.
                let total: usize = (0..shards).map(|s| t.local_count(s)).sum();
                assert_eq!(total, vocab);
                for s in 0..shards {
                    let ids: Vec<usize> = t.shard_ids(s).collect();
                    assert_eq!(ids.len(), t.local_count(s));
                    for (l, &g) in ids.iter().enumerate() {
                        assert_eq!(t.locate(g), (s, l));
                    }
                }
            }
        }
    }

    #[test]
    fn range_split_is_balanced_and_ordered() {
        let t = topo(10, ShardStrategy::Range, 3);
        // 10 over 3: 4 + 3 + 3, contiguous.
        let groups: Vec<Vec<usize>> = (0..3).map(|s| t.shard_ids(s).collect()).collect();
        assert_eq!(groups[0], vec![0, 1, 2, 3]);
        assert_eq!(groups[1], vec![4, 5, 6]);
        assert_eq!(groups[2], vec![7, 8, 9]);
    }

    #[test]
    fn hash_split_interleaves_the_head() {
        let t = topo(10, ShardStrategy::Hash, 3);
        let head: Vec<usize> = (0..3).map(|g| t.locate(g).0).collect();
        assert_eq!(head, vec![0, 1, 2], "consecutive hot ids must spread across shards");
        assert_eq!(t.shard_ids(1).collect::<Vec<_>>(), vec![1, 4, 7]);
    }

    #[test]
    fn shard_range_matches_snapshot_validation() {
        for strategy in [ShardStrategy::Range, ShardStrategy::Hash] {
            let t = topo(11, strategy, 3);
            for s in 0..3 {
                let sr = t.shard_range(s);
                sr.validate(t.local_count(s) as u64).unwrap();
                assert_eq!(sr.local_count() as usize, t.local_count(s));
            }
        }
    }

    #[test]
    fn parses_cluster_section() {
        let t = Topology::parse(
            r#"
[cluster]
vocab = 1000
strategy = "hash"
shard0 = ["127.0.0.1:7001", "127.0.0.1:7101"]
shard1 = "127.0.0.1:7002"    # single replica without brackets
"#,
        )
        .unwrap();
        assert_eq!(t.vocab(), 1000);
        assert_eq!(t.strategy(), ShardStrategy::Hash);
        assert_eq!(t.n_shards(), 2);
        assert_eq!(t.replicas(0).len(), 2);
        assert_eq!(t.replicas(1), &["127.0.0.1:7002".to_string()]);
        assert_eq!(t.n_replicas(), 3);

        // Round-trips through its own TOML rendering.
        let back = Topology::parse(&t.to_toml()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn rejects_malformed_topologies() {
        assert!(Topology::parse("[cluster]\nvocab = 10\n").is_err(), "no shards");
        assert!(
            Topology::parse("[cluster]\nshard0 = [\"a:1\"]\n").is_err(),
            "missing vocab"
        );
        assert!(
            Topology::parse("[cluster]\nvocab = 10\nstrategy = \"ring\"\nshard0 = [\"a:1\"]\n")
                .is_err(),
            "unknown strategy"
        );
        assert!(
            Topology::parse("[cluster]\nvocab = 10\nshard0 = [\"a:1\"]\nshard2 = [\"a:3\"]\n")
                .is_err(),
            "a numbering gap must be rejected, not silently truncated"
        );
        assert!(Topology::new(2, ShardStrategy::Range, vec![vec![]]).is_err(), "empty group");
        let too_many = (0..3).map(|i| vec![format!("a:{i}")]).collect();
        assert!(Topology::new(2, ShardStrategy::Range, too_many).is_err(), "empty shards");
    }
}
