//! Replica health tracking: consecutive-failure ejection with probe-driven
//! re-admission.
//!
//! Every replica is `healthy` until `eject_after` *consecutive* failures
//! (request transport errors and failed `PING` probes both count; any
//! success resets the streak). An ejected replica is skipped by the
//! router's first-choice replica selection — killing a node degrades tail
//! latency (one failed attempt per in-flight request until ejection), never
//! correctness — and is re-admitted the moment a probe (or a desperate
//! last-resort request, see the router's two-pass selection) succeeds
//! again. All state is atomics: health checks sit on the request path and
//! must not take locks.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};

/// One replica's failure-streak state.
#[derive(Debug, Default)]
struct ReplicaHealth {
    consecutive_failures: AtomicU32,
    ejected: AtomicBool,
    ejections: AtomicU64,
}

/// Health state for every replica in the cluster, indexed `[shard][replica]`.
#[derive(Debug)]
pub struct HealthBoard {
    replicas: Vec<Vec<ReplicaHealth>>,
    eject_after: u32,
}

impl HealthBoard {
    /// `shape[s]` is shard `s`'s replica count; `eject_after` is the
    /// consecutive-failure threshold (clamped to ≥ 1).
    pub fn new(shape: &[usize], eject_after: u32) -> HealthBoard {
        HealthBoard {
            replicas: shape
                .iter()
                .map(|&n| (0..n).map(|_| ReplicaHealth::default()).collect())
                .collect(),
            eject_after: eject_after.max(1),
        }
    }

    pub fn is_healthy(&self, shard: usize, replica: usize) -> bool {
        !self.replicas[shard][replica].ejected.load(Ordering::Relaxed)
    }

    /// A request or probe succeeded: reset the streak and re-admit.
    pub fn record_success(&self, shard: usize, replica: usize) {
        let r = &self.replicas[shard][replica];
        r.consecutive_failures.store(0, Ordering::Relaxed);
        r.ejected.store(false, Ordering::Relaxed);
    }

    /// A request or probe failed; returns `true` if this failure crossed
    /// the ejection threshold.
    pub fn record_failure(&self, shard: usize, replica: usize) -> bool {
        let r = &self.replicas[shard][replica];
        let streak = r.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if streak >= self.eject_after && !r.ejected.swap(true, Ordering::Relaxed) {
            r.ejections.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    pub fn healthy_count(&self) -> usize {
        self.replicas
            .iter()
            .enumerate()
            .map(|(s, group)| (0..group.len()).filter(|&r| self.is_healthy(s, r)).count())
            .sum()
    }

    pub fn total(&self) -> usize {
        self.replicas.iter().map(Vec::len).sum()
    }

    /// Healthy replicas within one shard.
    pub fn healthy_in_shard(&self, shard: usize) -> usize {
        (0..self.replicas[shard].len()).filter(|&r| self.is_healthy(shard, r)).count()
    }

    /// Lifetime ejection events across the cluster (monotonic).
    pub fn ejections(&self) -> u64 {
        self.replicas
            .iter()
            .flat_map(|g| g.iter())
            .map(|r| r.ejections.load(Ordering::Relaxed))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ejects_after_consecutive_failures_only() {
        let b = HealthBoard::new(&[2, 1], 3);
        assert_eq!(b.total(), 3);
        assert_eq!(b.healthy_count(), 3);

        // Interleaved successes keep resetting the streak.
        for _ in 0..5 {
            assert!(!b.record_failure(0, 0));
            assert!(!b.record_failure(0, 0));
            b.record_success(0, 0);
        }
        assert!(b.is_healthy(0, 0));

        // Three in a row ejects — exactly once.
        assert!(!b.record_failure(0, 0));
        assert!(!b.record_failure(0, 0));
        assert!(b.record_failure(0, 0), "third consecutive failure must eject");
        assert!(!b.record_failure(0, 0), "already ejected");
        assert!(!b.is_healthy(0, 0));
        assert_eq!(b.healthy_count(), 2);
        assert_eq!(b.healthy_in_shard(0), 1);
        assert_eq!(b.ejections(), 1);

        // Other replicas are untouched.
        assert!(b.is_healthy(0, 1));
        assert!(b.is_healthy(1, 0));
    }

    #[test]
    fn readmission_on_success() {
        let b = HealthBoard::new(&[1], 1);
        assert!(b.record_failure(0, 0), "threshold 1 ejects immediately");
        assert!(!b.is_healthy(0, 0));
        // The node came back: one successful probe re-admits it.
        b.record_success(0, 0);
        assert!(b.is_healthy(0, 0));
        // And the streak restarted from zero.
        assert!(b.record_failure(0, 0));
        assert_eq!(b.ejections(), 2);
    }

    #[test]
    fn zero_threshold_clamps_to_one() {
        let b = HealthBoard::new(&[1], 0);
        assert!(b.record_failure(0, 0));
        assert!(!b.is_healthy(0, 0));
    }
}
