//! The router as a server: one listener that makes N shard servers look
//! like a single embedding service.
//!
//! Speaks the *same* two protocols upstream that the single-node server
//! does (first-byte sniff: binary `MAGIC` vs line-oriented text), so every
//! existing client — [`BinaryClient`](crate::serving::BinaryClient), the
//! text protocol, the load generators — points at a router unchanged. The
//! listener itself is a [`net::Service`] impl over the shared serving core,
//! so the router runs on either network driver (`[net] driver`), exactly
//! like the single node. Request semantics differ from a single node only
//! where the cluster adds meaning:
//!
//! * `STATS` answers the cluster roll-up ([`Router::stats`]); the text form
//!   appends `healthy_replicas= total_replicas= failovers= shards=
//!   max_generation=` extras after the standard fields. The standard
//!   `accept_errors` field counts this listener's own survived accept
//!   failures on top of the sum reported by the shards.
//! * `RELOAD <dir>` / `OP_RELOAD` takes a *directory* of canonical
//!   `shard<i>.snap` files and performs the zero-downtime rolling reload
//!   across every replica of every shard, replying with the cluster's new
//!   (minimum) generation.
//! * `PING` answers from the router itself — liveness of the routing tier,
//!   not of any shard.
//! * `METRICS` / `OP_METRICS` answers the cluster-wide roll-up
//!   ([`Router::metrics`]): the router's own families followed by every
//!   replica's exposition re-labelled with `shard`/`replica`;
//!   `METRICS?slow` answers the router's own slow-query ring.
//! * `TRACE <id>` / `OP_TRACE` answers the cluster-assembled span tree
//!   ([`Router::trace_text`]): the router's own spans for the trace plus
//!   every replica's spans scraped over `OP_TRACE` and re-labelled with
//!   `shard`/`replica`; `TRACE?slow` answers the router's own
//!   completed-trace ring. A client frame carrying the trace-context
//!   extension bit routes through the traced paths, so the propagated
//!   context parents the router span and, through the fan-out, every
//!   shard-side span.

use super::router::{ClusterStats, Router, RouterConfig, RouterError};
use super::topology::Topology;
use crate::error::{Error, Result};
use crate::net::{self, Lifecycle, TextAction};
use crate::serving::wire::{self, BinRequest};
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared router-listener state (mirrors `coordinator::server::ServerState`).
pub struct RouterState {
    router: Router,
    lifecycle: Arc<Lifecycle>,
    /// Transient accept(2) failures survived by *this* listener, folded
    /// into the aggregate `accept_errors` STATS field on top of the shard
    /// servers' own counts.
    accept_errors: AtomicU64,
}

impl RouterState {
    pub fn new(router: Router) -> RouterState {
        RouterState {
            router,
            lifecycle: Lifecycle::new(),
            accept_errors: AtomicU64::new(0),
        }
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Begin graceful shutdown: stop accepting, drain in-flight requests,
    /// close connections. The probe loop and connection pools are torn
    /// down by [`accept_loop`] after the drain completes.
    pub fn shutdown(&self) {
        self.lifecycle.begin_shutdown();
    }

    /// The listener's shutdown/drain handle.
    pub fn lifecycle(&self) -> &Arc<Lifecycle> {
        &self.lifecycle
    }

    /// Cluster roll-up with this listener's own accept errors folded into
    /// the shared `accept_errors` field (shards report theirs via their
    /// STATS frames; the router adds its own listener's count).
    fn stats_rollup(&self) -> ClusterStats {
        let mut cs = self.router.stats();
        cs.aggregate.accept_errors += self.accept_errors.load(Ordering::Relaxed);
        cs
    }

    fn stats_line(&self) -> String {
        let cs = self.stats_rollup();
        // Standard fields through the one shared renderer; cluster extras
        // ride after (the drift helper tolerates extras, and single-node
        // parsers ignore unknown keys).
        format!(
            "{} healthy_replicas={} total_replicas={} failovers={} shards={} \
             max_generation={}\n",
            wire::format_stats_line(&cs.aggregate.fields()),
            cs.healthy_replicas,
            cs.total_replicas,
            cs.failovers,
            self.router.topology().n_shards(),
            cs.max_generation
        )
    }
}

fn err_line(e: &RouterError) -> String {
    format!("ERR {e}\n")
}

/// Dispatch one text-protocol line to a response; both network drivers
/// funnel through here via the [`net::Service`] impl.
fn dispatch_text(state: &RouterState, line: &str) -> TextAction {
    let router = &state.router;
    let parts: Vec<&str> = line.split_whitespace().collect();
    let response = match parts.as_slice() {
        [] => String::new(),
        ["QUIT"] => return TextAction::Quit,
        ["PING"] => "OK\n".to_string(),
        ["PING", ..] => "ERR PING takes no arguments\n".to_string(),
        ["STATS"] => state.stats_line(),
        ["METRICS"] => router.metrics(),
        ["METRICS?slow"] => router.metrics_slow_text(),
        ["METRICS" | "METRICS?slow", ..] => "ERR METRICS takes no arguments\n".to_string(),
        ["TRACE?slow"] => router.trace_slow_text(),
        ["TRACE", id] => match crate::obs::TraceContext::parse_hex(id) {
            Some(trace_id) => router.trace_text(trace_id),
            None => "ERR bad trace id\n".to_string(),
        },
        ["TRACE" | "TRACE?slow", ..] => "ERR TRACE takes <trace id>\n".to_string(),
        ["LOOKUP"] => err_line(&RouterError::BadQuery),
        ["LOOKUP", rest @ ..] if rest.len() > wire::MAX_IDS as usize => {
            "ERR too many ids\n".to_string()
        }
        ["LOOKUP", rest @ ..] => {
            match rest
                .iter()
                .map(|s| s.parse::<u32>())
                .collect::<std::result::Result<Vec<_>, _>>()
            {
                Ok(ids) => match router.lookup(&ids) {
                    Ok(rows) => crate::coordinator::server::rows_lines(rows),
                    Err(e) => err_line(&e),
                },
                Err(_) => "ERR bad id\n".to_string(),
            }
        }
        ["DOT", a, b] => match (a.parse::<u32>(), b.parse::<u32>()) {
            (Ok(a), Ok(b)) => match router.dot(a, b) {
                Ok(d) => format!("OK {d}\n"),
                Err(e) => err_line(&e),
            },
            _ => "ERR bad id\n".to_string(),
        },
        ["DOT", ..] => "ERR DOT takes exactly two ids\n".to_string(),
        ["KNN", id, k] => match (id.parse::<u32>(), k.parse::<u32>()) {
            (Ok(id), Ok(k)) => match router.knn(id, k) {
                Ok(neighbors) => crate::coordinator::server::neighbors_line(&neighbors),
                Err(e) => err_line(&e),
            },
            _ => "ERR bad id\n".to_string(),
        },
        ["KNN", ..] => "ERR KNN takes <query id> <k>\n".to_string(),
        ["RELOAD", dir] => match router.rolling_reload_dir(std::path::Path::new(dir)) {
            Ok(generations) => {
                let min = generations.iter().copied().min().unwrap_or(0);
                format!("OK generation={min}\n")
            }
            Err(e) => format!("ERR reload: {e}\n"),
        },
        ["RELOAD", ..] => "ERR RELOAD takes <shard snapshot dir>\n".to_string(),
        _ => "ERR unknown command\n".to_string(),
    };
    TextAction::Reply(response)
}

/// Append the response frame for one decoded binary request; mirrors
/// `wire::respond_binary` but dispatches into the [`Router`] instead of a
/// local [`ServingState`](crate::serving::ServingState). Returns true when
/// the connection must close after the bytes flush.
fn respond_binary_router(state: &RouterState, req: BinRequest, out: &mut Vec<u8>) -> bool {
    match req {
        // Unwrap a propagated trace context and dispatch through the
        // router's traced paths; the response bytes are identical to the
        // untraced dispatch by construction.
        BinRequest::Traced { ctx, parse_us, inner } => {
            dispatch_binary_router(state, *inner, Some((ctx, parse_us)), out)
        }
        other => dispatch_binary_router(state, other, None, out),
    }
}

fn dispatch_binary_router(
    state: &RouterState,
    req: BinRequest,
    trace: Option<(crate::obs::TraceContext, u64)>,
    out: &mut Vec<u8>,
) -> bool {
    let router = &state.router;
    match req {
        // Decoders never nest contexts; a hand-built nested frame is a
        // semantic error (the frame was consumed, connection survives).
        BinRequest::Traced { .. } => {
            wire::put_u32(out, wire::STATUS_BAD_REQUEST);
            wire::put_u32(out, 0);
            false
        }
        BinRequest::Fatal => {
            wire::put_u32(out, wire::STATUS_BAD_FRAME);
            wire::put_u32(out, 0);
            true
        }
        BinRequest::Reload { path: None } => {
            wire::put_u32(out, wire::STATUS_BAD_FRAME);
            wire::put_u32(out, 0);
            false
        }
        BinRequest::Reload { path: Some(dir) } => {
            match router.rolling_reload_dir(std::path::Path::new(&dir)) {
                Ok(generations) => {
                    let min = generations.iter().copied().min().unwrap_or(0);
                    wire::put_u32(out, wire::STATUS_OK);
                    wire::put_u32(out, 1);
                    wire::put_u32(out, min as u32);
                }
                Err(e) => {
                    crate::warn!("cluster RELOAD {dir:?} failed: {e}");
                    wire::put_u32(out, wire::STATUS_RELOAD_FAILED);
                    wire::put_u32(out, 0);
                }
            }
            false
        }
        BinRequest::KnnVec { k: 0, .. } => {
            wire::put_u32(out, wire::STATUS_BAD_REQUEST);
            wire::put_u32(out, 0);
            false
        }
        BinRequest::KnnVec { k, query } => {
            match router.knn_vec_traced(&query, k, trace) {
                Ok(neighbors) => {
                    let _ = wire::write_neighbors_frame(out, neighbors.iter().copied());
                }
                Err(e) => {
                    wire::put_u32(out, e.status_code());
                    wire::put_u32(out, 0);
                }
            }
            false
        }
        BinRequest::Ids { op: wire::OP_QUIT, .. } => true, // closes silently
        BinRequest::Ids { op, ids } => {
            match op {
                wire::OP_PING if ids.is_empty() => {
                    wire::put_u32(out, wire::STATUS_OK);
                    wire::put_u32(out, 0);
                }
                wire::OP_PING => {
                    wire::put_u32(out, wire::STATUS_BAD_REQUEST);
                    wire::put_u32(out, 0);
                }
                wire::OP_LOOKUP if !ids.is_empty() => match router.lookup_traced(&ids, trace) {
                    Ok(rows) => {
                        let row_bytes: usize = rows.iter().map(|r| r.len() * 4).sum();
                        out.reserve(8 + row_bytes);
                        wire::put_u32(out, wire::STATUS_OK);
                        wire::put_u32(out, rows.len() as u32);
                        for row in &rows {
                            wire::put_f32s(out, row);
                        }
                    }
                    Err(e) => {
                        wire::put_u32(out, e.status_code());
                        wire::put_u32(out, 0);
                    }
                },
                wire::OP_DOT if ids.len() == 2 => match router.dot(ids[0], ids[1]) {
                    Ok(d) => {
                        wire::put_u32(out, wire::STATUS_OK);
                        wire::put_u32(out, 1);
                        wire::put_f32s(out, &[d]);
                    }
                    Err(e) => {
                        wire::put_u32(out, e.status_code());
                        wire::put_u32(out, 0);
                    }
                },
                wire::OP_KNN if ids.len() == 2 && ids[1] == 0 => {
                    wire::put_u32(out, wire::STATUS_BAD_FRAME);
                    wire::put_u32(out, 0);
                }
                wire::OP_KNN if ids.len() == 2 => match router.knn_traced(ids[0], ids[1], trace) {
                    Ok(neighbors) => {
                        let _ = wire::write_neighbors_frame(out, neighbors.iter().copied());
                    }
                    Err(e) => {
                        wire::put_u32(out, e.status_code());
                        wire::put_u32(out, 0);
                    }
                },
                wire::OP_STATS => {
                    let _ = wire::write_stats_frame(out, &state.stats_rollup().aggregate.fields());
                }
                wire::OP_METRICS if ids.is_empty() => {
                    let text = state.router.metrics();
                    wire::put_u32(out, wire::STATUS_OK);
                    wire::put_u32(out, text.len() as u32);
                    out.extend_from_slice(text.as_bytes());
                }
                wire::OP_METRICS => {
                    wire::put_u32(out, wire::STATUS_BAD_REQUEST);
                    wire::put_u32(out, 0);
                }
                // Cluster-assembled trace by id (four little-endian u32
                // words) — the binary twin of the text `TRACE <hex id>`.
                wire::OP_TRACE if ids.len() == 4 => {
                    let text = router.trace_text(wire::trace_id_from_words(&ids));
                    wire::put_u32(out, wire::STATUS_OK);
                    wire::put_u32(out, text.len() as u32);
                    out.extend_from_slice(text.as_bytes());
                }
                // No id: the router's own completed-trace ring.
                wire::OP_TRACE if ids.is_empty() => {
                    let text = router.trace_slow_text();
                    wire::put_u32(out, wire::STATUS_OK);
                    wire::put_u32(out, text.len() as u32);
                    out.extend_from_slice(text.as_bytes());
                }
                // Any other TRACE id count is a bad request — mirrors PING.
                wire::OP_TRACE => {
                    wire::put_u32(out, wire::STATUS_BAD_REQUEST);
                    wire::put_u32(out, 0);
                }
                _ => {
                    wire::put_u32(out, wire::STATUS_BAD_FRAME);
                    wire::put_u32(out, 0);
                }
            }
            false
        }
    }
}

impl net::Service for RouterState {
    /// The dimensionality comes from the first downstream hello. If no
    /// shard-0 replica is reachable there is nothing truthful to negotiate
    /// — refuse the connection (the client sees a failed handshake and
    /// retries later) rather than cache dim=0 in the client for the
    /// connection's lifetime, which would desync its row framing the
    /// moment the shards come up.
    fn hello_dim(&self) -> Option<u32> {
        self.router.dim().ok().map(|d| d as u32)
    }

    fn text(&self, line: &str) -> TextAction {
        dispatch_text(self, line)
    }

    fn binary(&self, req: BinRequest, out: &mut Vec<u8>) -> bool {
        respond_binary_router(self, req, out)
    }

    fn note_accept_error(&self) {
        self.accept_errors.fetch_add(1, Ordering::Relaxed);
    }

    fn obs(&self) -> Option<Arc<crate::obs::Obs>> {
        Some(self.router.obs())
    }
}

/// Start router state + listener without blocking (tests, examples).
/// Returns (state, listener, bound address).
pub fn spawn(
    topo: Topology,
    cfg: RouterConfig,
    addr: &str,
) -> Result<(Arc<RouterState>, TcpListener, String)> {
    let state = Arc::new(RouterState::new(Router::new(topo, cfg)));
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Server(format!("bind {addr}: {e}")))?;
    let bound =
        listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| addr.to_string());
    Ok((state, listener, bound))
}

/// Serve until [`RouterState::shutdown`], then drain, close connections,
/// join handler threads, and stop the probe loop. Runs on the `[net]`
/// driver from the router config.
pub fn accept_loop(listener: TcpListener, state: Arc<RouterState>) {
    let cfg = state.router.config().net;
    let lifecycle = state.lifecycle.clone();
    let svc: Arc<dyn net::Service> = state.clone();
    net::serve(listener, svc, &cfg, lifecycle);
    state.router.shutdown();
}

/// Run the router until shutdown (`w2k cluster route`).
pub fn serve_blocking(topo: Topology, cfg: RouterConfig, addr: &str) -> Result<()> {
    let (state, listener, bound) = spawn(topo, cfg, addr)?;
    crate::info!(
        "cluster router on {bound} ({}, {} driver), probing every {:?}",
        state.router.topology().describe(),
        cfg.net.driver,
        cfg.probe_interval
    );
    accept_loop(listener, state);
    Ok(())
}
