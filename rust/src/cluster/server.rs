//! The router as a server: one listener that makes N shard servers look
//! like a single embedding service.
//!
//! Speaks the *same* two protocols upstream that the single-node server
//! does (first-byte sniff: binary `MAGIC` vs line-oriented text), so every
//! existing client — [`BinaryClient`](crate::serving::BinaryClient), the
//! text protocol, the load generators — points at a router unchanged.
//! Request semantics differ from a single node only where the cluster adds
//! meaning:
//!
//! * `STATS` answers the cluster roll-up ([`Router::stats`]); the text form
//!   appends `healthy_replicas= total_replicas= failovers= shards=
//!   max_generation=` extras after the standard fields.
//! * `RELOAD <dir>` / `OP_RELOAD` takes a *directory* of canonical
//!   `shard<i>.snap` files and performs the zero-downtime rolling reload
//!   across every replica of every shard, replying with the cluster's new
//!   (minimum) generation.
//! * `PING` answers from the router itself — liveness of the routing tier,
//!   not of any shard.

use super::router::{Router, RouterConfig, RouterError};
use super::topology::Topology;
use crate::error::{Error, Result};
use crate::serving::wire;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Shared router-listener state (mirrors `coordinator::server::ServerState`).
pub struct RouterState {
    router: Router,
    stop: AtomicBool,
}

impl RouterState {
    pub fn new(router: Router) -> RouterState {
        RouterState { router, stop: AtomicBool::new(false) }
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.router.shutdown();
    }

    fn stats_line(&self) -> String {
        let cs = self.router.stats();
        // Standard fields through the one shared renderer; cluster extras
        // ride after (the drift helper tolerates extras, and single-node
        // parsers ignore unknown keys).
        format!(
            "{} healthy_replicas={} total_replicas={} failovers={} shards={} \
             max_generation={}\n",
            wire::format_stats_line(&cs.aggregate.fields()),
            cs.healthy_replicas,
            cs.total_replicas,
            cs.failovers,
            self.router.topology().n_shards(),
            cs.max_generation
        )
    }
}

fn err_line(e: &RouterError) -> String {
    format!("ERR {e}\n")
}

/// Same request-line cap as the single-node text handler.
const MAX_LINE_BYTES: u64 = 1 << 20;

fn handle_text(reader: &mut BufReader<TcpStream>, writer: &mut TcpStream, state: &RouterState) {
    let router = &state.router;
    let mut line = String::new();
    loop {
        line.clear();
        match (&mut *reader).take(MAX_LINE_BYTES).read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        if line.len() as u64 >= MAX_LINE_BYTES && !line.ends_with('\n') {
            let _ = writer.write_all(b"ERR line too long\n");
            break;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        let response = match parts.as_slice() {
            [] => continue,
            ["QUIT"] => break,
            ["PING"] => "OK\n".to_string(),
            ["PING", ..] => "ERR PING takes no arguments\n".to_string(),
            ["STATS"] => state.stats_line(),
            ["LOOKUP"] => err_line(&RouterError::BadQuery),
            ["LOOKUP", rest @ ..] if rest.len() > wire::MAX_IDS as usize => {
                "ERR too many ids\n".to_string()
            }
            ["LOOKUP", rest @ ..] => {
                match rest
                    .iter()
                    .map(|s| s.parse::<u32>())
                    .collect::<std::result::Result<Vec<_>, _>>()
                {
                    Ok(ids) => match router.lookup(&ids) {
                        Ok(rows) => crate::coordinator::server::rows_lines(rows),
                        Err(e) => err_line(&e),
                    },
                    Err(_) => "ERR bad id\n".to_string(),
                }
            }
            ["DOT", a, b] => match (a.parse::<u32>(), b.parse::<u32>()) {
                (Ok(a), Ok(b)) => match router.dot(a, b) {
                    Ok(d) => format!("OK {d}\n"),
                    Err(e) => err_line(&e),
                },
                _ => "ERR bad id\n".to_string(),
            },
            ["DOT", ..] => "ERR DOT takes exactly two ids\n".to_string(),
            ["KNN", id, k] => match (id.parse::<u32>(), k.parse::<u32>()) {
                (Ok(id), Ok(k)) => match router.knn(id, k) {
                    Ok(neighbors) => crate::coordinator::server::neighbors_line(&neighbors),
                    Err(e) => err_line(&e),
                },
                _ => "ERR bad id\n".to_string(),
            },
            ["KNN", ..] => "ERR KNN takes <query id> <k>\n".to_string(),
            ["RELOAD", dir] => match router.rolling_reload_dir(std::path::Path::new(dir)) {
                Ok(generations) => {
                    let min = generations.iter().copied().min().unwrap_or(0);
                    format!("OK generation={min}\n")
                }
                Err(e) => format!("ERR reload: {e}\n"),
            },
            ["RELOAD", ..] => "ERR RELOAD takes <shard snapshot dir>\n".to_string(),
            _ => "ERR unknown command\n".to_string(),
        };
        if writer.write_all(response.as_bytes()).is_err() {
            break;
        }
    }
}

fn handle_binary(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    state: &RouterState,
) -> std::io::Result<()> {
    let router = &state.router;
    // Hello: the dimensionality comes from the first downstream hello. If
    // no shard-0 replica is reachable there is nothing truthful to
    // negotiate — refuse the connection (the client sees a failed
    // handshake and retries later) rather than cache dim=0 in the client
    // for the connection's lifetime, which would desync its row framing
    // the moment the shards come up.
    let Ok(dim) = router.dim() else {
        return Ok(());
    };
    let mut hello = Vec::with_capacity(8);
    hello.extend_from_slice(&wire::MAGIC);
    wire::put_u32(&mut hello, dim as u32);
    writer.write_all(&hello)?;
    loop {
        let op = match wire::read_u32(reader) {
            Ok(op) => op,
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e),
        };
        let count = wire::read_u32(reader)?;
        if op == wire::OP_RELOAD {
            if count == 0 || count > wire::MAX_PATH_BYTES {
                return wire::write_error(writer, wire::STATUS_BAD_FRAME);
            }
            let mut raw = vec![0u8; count as usize];
            reader.read_exact(&mut raw)?;
            let Ok(dir) = String::from_utf8(raw) else {
                wire::write_error(writer, wire::STATUS_BAD_FRAME)?;
                continue;
            };
            match router.rolling_reload_dir(std::path::Path::new(&dir)) {
                Ok(generations) => {
                    let min = generations.iter().copied().min().unwrap_or(0);
                    let mut buf = Vec::with_capacity(12);
                    wire::put_u32(&mut buf, wire::STATUS_OK);
                    wire::put_u32(&mut buf, 1);
                    wire::put_u32(&mut buf, min as u32);
                    writer.write_all(&buf)?;
                }
                Err(e) => {
                    crate::warn!("cluster RELOAD {dir:?} failed: {e}");
                    wire::write_error(writer, wire::STATUS_RELOAD_FAILED)?;
                }
            }
            continue;
        }
        if op == wire::OP_KNN_VEC {
            if count == 0 || count > wire::MAX_IDS {
                return wire::write_error(writer, wire::STATUS_BAD_FRAME);
            }
            let k = wire::read_u32(reader)?;
            let query = wire::read_f32s(reader, count as usize)?;
            if k == 0 {
                wire::write_error(writer, wire::STATUS_BAD_REQUEST)?;
                continue;
            }
            match router.knn_vec(&query, k) {
                Ok(neighbors) => wire::write_neighbors_frame(writer, neighbors.iter().copied())?,
                Err(e) => wire::write_error(writer, e.status_code())?,
            }
            continue;
        }
        if count > wire::MAX_IDS {
            return wire::write_error(writer, wire::STATUS_BAD_FRAME);
        }
        let mut ids = Vec::with_capacity(count as usize);
        for _ in 0..count {
            ids.push(wire::read_u32(reader)?);
        }
        match op {
            wire::OP_QUIT => return Ok(()),
            wire::OP_PING if ids.is_empty() => {
                let mut buf = Vec::with_capacity(8);
                wire::put_u32(&mut buf, wire::STATUS_OK);
                wire::put_u32(&mut buf, 0);
                writer.write_all(&buf)?;
            }
            wire::OP_PING => wire::write_error(writer, wire::STATUS_BAD_REQUEST)?,
            wire::OP_LOOKUP if !ids.is_empty() => match router.lookup(&ids) {
                Ok(rows) => {
                    let mut buf = Vec::with_capacity(8 + rows.len() * dim * 4);
                    wire::put_u32(&mut buf, wire::STATUS_OK);
                    wire::put_u32(&mut buf, rows.len() as u32);
                    for row in &rows {
                        wire::put_f32s(&mut buf, row);
                    }
                    writer.write_all(&buf)?;
                }
                Err(e) => wire::write_error(writer, e.status_code())?,
            },
            wire::OP_DOT if ids.len() == 2 => match router.dot(ids[0], ids[1]) {
                Ok(d) => {
                    let mut buf = Vec::with_capacity(12);
                    wire::put_u32(&mut buf, wire::STATUS_OK);
                    wire::put_u32(&mut buf, 1);
                    wire::put_f32s(&mut buf, &[d]);
                    writer.write_all(&buf)?;
                }
                Err(e) => wire::write_error(writer, e.status_code())?,
            },
            wire::OP_KNN if ids.len() == 2 && ids[1] == 0 => {
                wire::write_error(writer, wire::STATUS_BAD_FRAME)?
            }
            wire::OP_KNN if ids.len() == 2 => match router.knn(ids[0], ids[1]) {
                Ok(neighbors) => wire::write_neighbors_frame(writer, neighbors.iter().copied())?,
                Err(e) => wire::write_error(writer, e.status_code())?,
            },
            wire::OP_STATS => {
                wire::write_stats_frame(writer, &router.stats().aggregate.fields())?;
            }
            _ => wire::write_error(writer, wire::STATUS_BAD_FRAME)?,
        }
    }
}

fn handle_conn(stream: TcpStream, state: Arc<RouterState>) {
    let peer = stream.peer_addr().ok();
    let Ok(clone) = stream.try_clone() else { return };
    let mut reader = BufReader::new(clone);
    let mut writer = stream;
    let first = match reader.fill_buf() {
        Ok(buf) if !buf.is_empty() => buf[0],
        _ => return,
    };
    if first == wire::MAGIC[0] {
        let mut magic = [0u8; 4];
        if reader.read_exact(&mut magic).is_err() || magic != wire::MAGIC {
            let _ = writer.write_all(b"ERR bad magic\n");
            return;
        }
        if let Err(e) = handle_binary(&mut reader, &mut writer, &state) {
            crate::debug!("cluster binary conn {peer:?} ended: {e}");
        }
    } else {
        handle_text(&mut reader, &mut writer, &state);
    }
}

/// Start router state + listener without blocking (tests, examples).
/// Returns (state, listener, bound address).
pub fn spawn(
    topo: Topology,
    cfg: RouterConfig,
    addr: &str,
) -> Result<(Arc<RouterState>, TcpListener, String)> {
    let state = Arc::new(RouterState::new(Router::new(topo, cfg)));
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Server(format!("bind {addr}: {e}")))?;
    let bound =
        listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| addr.to_string());
    Ok((state, listener, bound))
}

/// Accept-loop helper: serve until `state.stop` flips.
pub fn accept_loop(listener: TcpListener, state: Arc<RouterState>) {
    listener.set_nonblocking(true).ok();
    while !state.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((s, _)) => {
                let st = state.clone();
                std::thread::spawn(move || handle_conn(s, st));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
}

/// Run the router until the process dies (`w2k cluster route`).
pub fn serve_blocking(topo: Topology, cfg: RouterConfig, addr: &str) -> Result<()> {
    let (state, listener, bound) = spawn(topo, cfg, addr)?;
    crate::info!(
        "cluster router on {bound} ({}), probing every {:?}",
        state.router.topology().describe(),
        cfg.probe_interval
    );
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let st = state.clone();
                std::thread::spawn(move || handle_conn(s, st));
            }
            Err(e) => crate::warn!("accept error: {e}"),
        }
    }
    Ok(())
}
