//! Command-line argument parsing (clap substitute).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, repeated
//! options, positionals, and auto-generated help text.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Declarative option spec.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Boolean flag (no value) vs valued option.
    pub takes_value: bool,
    /// May appear multiple times.
    pub repeated: bool,
    pub default: Option<&'static str>,
}

/// A subcommand definition.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments for one subcommand.
#[derive(Debug, Clone, Default)]
pub struct Parsed {
    pub command: String,
    values: BTreeMap<String, Vec<String>>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<String> {
        self.values.get(name).cloned().unwrap_or_default()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| Error::Cli(format!("--{name} expects an integer, got '{s}'"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| Error::Cli(format!("--{name} expects a number, got '{s}'"))),
        }
    }
}

/// Top-level application parser.
#[derive(Debug, Clone)]
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl App {
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <COMMAND> [OPTIONS]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<18} {}\n", c.name, c.about));
        }
        s.push_str("\nRun '");
        s.push_str(self.name);
        s.push_str(" <COMMAND> --help' for command options.\n");
        s
    }

    pub fn command_help(&self, cmd: &CommandSpec) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, cmd.name, cmd.about);
        for o in &cmd.opts {
            let val = if o.takes_value { " <VALUE>" } else { "" };
            let dflt = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{:<24} {}{}\n", format!("{}{}", o.name, val), o.help, dflt));
        }
        if !cmd.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (name, help) in &cmd.positionals {
                s.push_str(&format!("  <{name}>  {help}\n"));
            }
        }
        s
    }

    /// Parse argv (excluding program name). Returns Err with the help text as
    /// the message when `--help` is requested.
    pub fn parse(&self, argv: &[String]) -> Result<Parsed> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Err(Error::Cli(self.help()));
        }
        let cmd_name = &argv[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| Error::Cli(format!("unknown command '{cmd_name}'\n\n{}", self.help())))?;

        let mut parsed = Parsed { command: cmd.name.to_string(), ..Default::default() };
        // Seed defaults.
        for o in &cmd.opts {
            if let Some(d) = o.default {
                parsed.values.insert(o.name.to_string(), vec![d.to_string()]);
            }
        }

        let mut i = 1;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(Error::Cli(self.command_help(cmd)));
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| Error::Cli(format!("unknown option '--{key}' for '{}'", cmd.name)))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| Error::Cli(format!("--{key} expects a value")))?
                        }
                    };
                    let slot = parsed.values.entry(key.to_string()).or_default();
                    if spec.repeated {
                        // keep defaults out of repeated accumulation
                        if spec.default.is_some()
                            && slot.len() == 1
                            && slot[0] == spec.default.unwrap_or("")
                            && !parsed.flags.get(key).copied().unwrap_or(false)
                        {
                            slot.clear();
                        }
                        parsed.flags.insert(key.to_string(), true);
                        slot.push(val);
                    } else {
                        *slot = vec![val];
                    }
                } else {
                    if inline_val.is_some() {
                        return Err(Error::Cli(format!("flag --{key} takes no value")));
                    }
                    parsed.flags.insert(key.to_string(), true);
                }
            } else {
                parsed.positionals.push(arg.clone());
            }
            i += 1;
        }
        if parsed.positionals.len() > cmd.positionals.len() {
            return Err(Error::Cli(format!(
                "too many positional arguments for '{}' (expected {})",
                cmd.name,
                cmd.positionals.len()
            )));
        }
        Ok(parsed)
    }
}

/// The `w2k` binary's CLI definition, shared with examples.
pub fn app() -> App {
    let common_train = vec![
        OptSpec { name: "config", help: "experiment config file (TOML subset)", takes_value: true, repeated: false, default: None },
        OptSpec { name: "set", help: "override config key, e.g. --set train.steps=100", takes_value: true, repeated: true, default: None },
        OptSpec { name: "artifacts", help: "artifacts directory", takes_value: true, repeated: false, default: Some("artifacts") },
        OptSpec { name: "verbose", help: "debug logging", takes_value: false, repeated: false, default: None },
    ];
    App {
        name: "w2k",
        about: "word2ket / word2ketXS reproduction: training, evaluation and serving",
        commands: vec![
            CommandSpec {
                name: "train",
                about: "train a model variant on a synthetic task",
                opts: common_train.clone(),
                positionals: vec![],
            },
            CommandSpec {
                name: "eval",
                about: "evaluate a checkpoint on the test split",
                opts: {
                    let mut o = common_train.clone();
                    o.push(OptSpec { name: "checkpoint", help: "checkpoint file to load", takes_value: true, repeated: false, default: None });
                    o
                },
                positionals: vec![],
            },
            CommandSpec {
                name: "serve",
                about: "serve compressed embedding lookups over TCP",
                opts: {
                    let mut o = common_train.clone();
                    o.push(OptSpec { name: "addr", help: "listen address", takes_value: true, repeated: false, default: Some("127.0.0.1:7878") });
                    o
                },
                positionals: vec![],
            },
            CommandSpec {
                name: "snapshot",
                about: "save, inspect or load embedding-store snapshots",
                opts: {
                    let mut o = common_train.clone();
                    o.push(OptSpec { name: "payload", help: "payload codec for save: f32|f16|int8|int4|b2|b1 (sub-byte codecs pack word2ket factors with an f16 refinement; default: [snapshot] codec)", takes_value: true, repeated: false, default: None });
                    o.push(OptSpec { name: "with-index", help: "embed the trained IVF index ([index] config) in the snapshot", takes_value: false, repeated: false, default: None });
                    o.push(OptSpec { name: "with-norms", help: "embed per-word L2 norms so cosine scorers skip the norm pass on load (f32 payloads only)", takes_value: false, repeated: false, default: None });
                    o.push(OptSpec { name: "mmap", help: "load via memory mapping (zero-copy) instead of heap read", takes_value: false, repeated: false, default: None });
                    o
                },
                positionals: vec![
                    ("action", "save | load | info"),
                    ("path", "snapshot file"),
                ],
            },
            CommandSpec {
                name: "cluster",
                about: "vocabulary-sharded multi-node serving: shard snapshots, scatter-gather router",
                opts: {
                    let mut o = common_train.clone();
                    o.push(OptSpec { name: "addr", help: "router listen address (route)", takes_value: true, repeated: false, default: Some("127.0.0.1:7900") });
                    o.push(OptSpec { name: "out", help: "shard snapshot directory (shard); also what rolling RELOAD deploys from", takes_value: true, repeated: false, default: Some("shards") });
                    o
                },
                positionals: vec![
                    ("action", "route | shard | status"),
                    ("topology", "topology TOML file with a [cluster] section"),
                ],
            },
            CommandSpec {
                name: "params",
                about: "print paper Tables 1-3 #Params / space-saving accounting",
                opts: vec![],
                positionals: vec![],
            },
            CommandSpec {
                name: "artifacts",
                about: "list and validate AOT artifacts against the manifest",
                opts: vec![OptSpec { name: "artifacts", help: "artifacts directory", takes_value: true, repeated: false, default: Some("artifacts") }],
                positionals: vec![],
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_train_with_overrides() {
        let a = app();
        let p = a
            .parse(&argv(&[
                "train",
                "--set",
                "embedding.kind=word2ketxs",
                "--set",
                "embedding.order=2",
                "--artifacts",
                "arts",
                "--verbose",
            ]))
            .unwrap();
        assert_eq!(p.command, "train");
        assert_eq!(p.get_all("set"), vec!["embedding.kind=word2ketxs", "embedding.order=2"]);
        assert_eq!(p.get("artifacts"), Some("arts"));
        assert!(p.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = app();
        let p = a.parse(&argv(&["serve", "--addr=0.0.0.0:9999"])).unwrap();
        assert_eq!(p.get("addr"), Some("0.0.0.0:9999"));
    }

    #[test]
    fn defaults_applied() {
        let a = app();
        let p = a.parse(&argv(&["train"])).unwrap();
        assert_eq!(p.get("artifacts"), Some("artifacts"));
    }

    #[test]
    fn snapshot_command_parses() {
        let a = app();
        let p = a
            .parse(&argv(&[
                "snapshot",
                "save",
                "model.snap",
                "--payload",
                "int8",
                "--with-index",
                "--with-norms",
            ]))
            .unwrap();
        assert_eq!(p.command, "snapshot");
        assert_eq!(p.positionals, vec!["save".to_string(), "model.snap".to_string()]);
        assert_eq!(p.get("payload"), Some("int8"));
        assert!(p.flag("with-index"));
        assert!(p.flag("with-norms"));
        assert!(!p.flag("mmap"));
        // Sub-byte payload codecs parse at the CLI layer like any other
        // value; validation happens in Codec::parse at save time.
        let p = a.parse(&argv(&["snapshot", "save", "m.snap", "--payload", "int4"])).unwrap();
        assert_eq!(p.get("payload"), Some("int4"));
        assert!(crate::snapshot::Codec::parse("b1").is_ok());
        let err = crate::snapshot::Codec::parse("int3").unwrap_err().to_string();
        assert!(err.contains("f32|f16|int8|int4|b2|b1"), "{err}");
        // Too many positionals is a CLI error.
        assert!(a.parse(&argv(&["snapshot", "save", "a.snap", "extra"])).is_err());
    }

    #[test]
    fn cluster_command_parses() {
        let a = app();
        let p = a
            .parse(&argv(&["cluster", "shard", "topo.toml", "--out", "deploy/shards"]))
            .unwrap();
        assert_eq!(p.command, "cluster");
        assert_eq!(p.positionals, vec!["shard".to_string(), "topo.toml".to_string()]);
        assert_eq!(p.get("out"), Some("deploy/shards"));
        let p = a.parse(&argv(&["cluster", "route", "topo.toml"])).unwrap();
        assert_eq!(p.get("addr"), Some("127.0.0.1:7900"));
        assert!(a.parse(&argv(&["cluster", "route", "t.toml", "x"])).is_err());
    }

    #[test]
    fn unknown_command_and_option() {
        let a = app();
        assert!(a.parse(&argv(&["fly"])).is_err());
        assert!(a.parse(&argv(&["train", "--bogus", "1"])).is_err());
        assert!(a.parse(&argv(&["train", "--set"])).is_err());
    }

    #[test]
    fn help_is_error_with_text() {
        let a = app();
        let e = a.parse(&argv(&["--help"])).unwrap_err().to_string();
        assert!(e.contains("COMMANDS"));
        let e2 = a.parse(&argv(&["train", "--help"])).unwrap_err().to_string();
        assert!(e2.contains("--config"));
    }

    #[test]
    fn typed_getters() {
        let a = App {
            name: "t",
            about: "",
            commands: vec![CommandSpec {
                name: "c",
                about: "",
                opts: vec![OptSpec { name: "n", help: "", takes_value: true, repeated: false, default: None }],
                positionals: vec![],
            }],
        };
        let p = a.parse(&argv(&["c", "--n", "42"])).unwrap();
        assert_eq!(p.get_usize("n").unwrap(), Some(42));
        let p2 = a.parse(&argv(&["c", "--n", "x"])).unwrap();
        assert!(p2.get_usize("n").is_err());
    }
}
