//! Parser for `artifacts/manifest.json` written by `python/compile/aot.py`.
//!
//! The manifest is the contract between the build path (L1/L2) and the
//! request path (L3): artifact file names, input ordering, shapes, dtypes,
//! parameter initialization specs, and model dimensions.

use crate::error::{Error, Result};
use crate::util::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Tensor dtype in the artifact interface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(Error::Artifact(format!("unsupported dtype {other}"))),
        }
    }
}

/// Shape + dtype of one non-parameter input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: req_str(j, "name")?,
            shape: req_shape(j, "shape")?,
            dtype: Dtype::parse(&req_str(j, "dtype")?)?,
        })
    }
}

/// Initialization spec for one parameter tensor (mirrored from python).
#[derive(Debug, Clone, PartialEq)]
pub enum Init {
    /// U(-a, a)
    Uniform { a: f64 },
    Zeros,
    Ones,
}

/// One trainable parameter tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl ParamSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered function of a variant.
#[derive(Debug, Clone, PartialEq)]
pub struct FunctionInfo {
    pub file: String,
    /// How many copies of the parameter list lead the input tuple
    /// (3 for train_step: params, m, v; 1 for inference functions).
    pub param_copies: usize,
    pub extra_inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Embedding description (for reports; authoritative accounting in stats.rs).
#[derive(Debug, Clone, PartialEq)]
pub struct EmbeddingInfo {
    pub kind: String,
    pub order: usize,
    pub rank: usize,
    pub q: usize,
    pub t: usize,
    pub num_params: usize,
}

/// One (task × embedding) model variant.
#[derive(Debug, Clone)]
pub struct VariantInfo {
    pub name: String,
    pub task: String,
    pub dims: BTreeMap<String, usize>,
    pub embedding: EmbeddingInfo,
    pub params: Vec<ParamSpec>,
    pub functions: BTreeMap<String, FunctionInfo>,
}

impl VariantInfo {
    pub fn dim(&self, key: &str) -> Result<usize> {
        self.dims
            .get(key)
            .copied()
            .ok_or_else(|| Error::Artifact(format!("variant {} missing dim {key}", self.name)))
    }

    pub fn function(&self, name: &str) -> Result<&FunctionInfo> {
        self.functions
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("variant {} has no function {name}", self.name)))
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.num_elements()).sum()
    }
}

/// Standalone kernel artifact (integration tests, microbenches).
#[derive(Debug, Clone)]
pub struct KernelInfo {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub source_hash: String,
    pub variants: BTreeMap<String, VariantInfo>,
    pub kernels: BTreeMap<String, KernelInfo>,
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| Error::Artifact(format!("manifest missing key '{key}'")))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    req(j, key)?
        .as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| Error::Artifact(format!("'{key}' is not a string")))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?
        .as_usize()
        .ok_or_else(|| Error::Artifact(format!("'{key}' is not a non-negative integer")))
}

fn req_shape(j: &Json, key: &str) -> Result<Vec<usize>> {
    req(j, key)?
        .as_arr()
        .ok_or_else(|| Error::Artifact(format!("'{key}' is not an array")))?
        .iter()
        .map(|x| {
            x.as_usize()
                .ok_or_else(|| Error::Artifact(format!("bad dim in '{key}'")))
        })
        .collect()
}

fn parse_init(j: &Json) -> Result<Init> {
    let dist = req_str(j, "dist")?;
    match dist.as_str() {
        "uniform" => Ok(Init::Uniform {
            a: req(j, "a")?
                .as_f64()
                .ok_or_else(|| Error::Artifact("'a' is not a number".into()))?,
        }),
        "zeros" => Ok(Init::Zeros),
        "ones" => Ok(Init::Ones),
        other => Err(Error::Artifact(format!("unknown init dist '{other}'"))),
    }
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src)?;
        let mut variants = BTreeMap::new();
        if let Some(vars) = j.get("variants").and_then(|v| v.as_obj()) {
            for (name, vj) in vars {
                variants.insert(name.clone(), Self::parse_variant(name, vj)?);
            }
        }
        let mut kernels = BTreeMap::new();
        if let Some(ks) = j.get("kernels").and_then(|v| v.as_obj()) {
            for (name, kj) in ks {
                kernels.insert(
                    name.clone(),
                    KernelInfo {
                        file: req_str(kj, "file")?,
                        inputs: parse_tensor_list(kj, "inputs")?,
                        outputs: parse_tensor_list(kj, "outputs")?,
                    },
                );
            }
        }
        Ok(Manifest {
            source_hash: j
                .get("source_hash")
                .and_then(|s| s.as_str())
                .unwrap_or("")
                .to_string(),
            variants,
            kernels,
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        Self::parse(&src)
    }

    fn parse_variant(name: &str, j: &Json) -> Result<VariantInfo> {
        let dims_j = req(j, "dims")?;
        let mut dims = BTreeMap::new();
        let mut task = String::new();
        if let Some(obj) = dims_j.as_obj() {
            for (k, v) in obj {
                if k == "task" {
                    task = v.as_str().unwrap_or("").to_string();
                } else if let Some(u) = v.as_usize() {
                    dims.insert(k.clone(), u);
                }
            }
        }
        let emb = req(j, "embedding")?;
        let embedding = EmbeddingInfo {
            kind: req_str(emb, "kind")?,
            order: req_usize(emb, "order")?,
            rank: req_usize(emb, "rank")?,
            q: req_usize(emb, "q")?,
            t: req_usize(emb, "t")?,
            num_params: req_usize(emb, "num_params")?,
        };
        let params = req(j, "params")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("'params' not an array".into()))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: req_str(p, "name")?,
                    shape: req_shape(p, "shape")?,
                    init: parse_init(req(p, "init")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut functions = BTreeMap::new();
        if let Some(fs) = j.get("functions").and_then(|f| f.as_obj()) {
            for (fname, fj) in fs {
                functions.insert(
                    fname.clone(),
                    FunctionInfo {
                        file: req_str(fj, "file")?,
                        param_copies: req_usize(fj, "param_copies")?,
                        extra_inputs: parse_tensor_list(fj, "extra_inputs")?,
                        outputs: parse_tensor_list(fj, "outputs")?,
                    },
                );
            }
        }
        Ok(VariantInfo { name: name.to_string(), task, dims, embedding, params, functions })
    }
}

fn parse_tensor_list(j: &Json, key: &str) -> Result<Vec<TensorSpec>> {
    req(j, key)?
        .as_arr()
        .ok_or_else(|| Error::Artifact(format!("'{key}' not an array")))?
        .iter()
        .map(TensorSpec::from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "source_hash": "abc",
      "variants": {
        "sum_regular": {
          "dims": {"task": "sum", "batch": 16, "vocab": 1024, "hidden": 64,
                   "src_len": 24, "tgt_len": 8, "emb_dim": 64},
          "embedding": {"kind": "regular", "order": 1, "rank": 1, "q": 64,
                        "t": 1024, "num_params": 65536},
          "params": [
            {"name": "emb/table", "shape": [1024, 64],
             "init": {"dist": "uniform", "a": 0.2165}},
            {"name": "out/b", "shape": [1024], "init": {"dist": "zeros"}}
          ],
          "functions": {
            "train_step": {
              "file": "sum_regular.train_step.hlo.txt",
              "param_copies": 3,
              "extra_inputs": [
                {"name": "src", "shape": [16, 24], "dtype": "i32"},
                {"name": "lr", "shape": [], "dtype": "f32"}
              ],
              "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]
            }
          }
        }
      },
      "kernels": {
        "kernel_kron_pair": {
          "file": "kernel_kron_pair.hlo.txt",
          "inputs": [{"name": "a", "shape": [16, 8], "dtype": "f32"}],
          "outputs": [{"name": "out", "shape": [16, 64], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.source_hash, "abc");
        let v = &m.variants["sum_regular"];
        assert_eq!(v.task, "sum");
        assert_eq!(v.dim("batch").unwrap(), 16);
        assert_eq!(v.embedding.kind, "regular");
        assert_eq!(v.params.len(), 2);
        assert_eq!(v.params[0].num_elements(), 65536);
        assert!(matches!(v.params[0].init, Init::Uniform { .. }));
        assert!(matches!(v.params[1].init, Init::Zeros));
        let f = v.function("train_step").unwrap();
        assert_eq!(f.param_copies, 3);
        assert_eq!(f.extra_inputs[0].dtype, Dtype::I32);
        assert_eq!(f.extra_inputs[1].shape.len(), 0);
        assert!(v.function("bogus").is_err());
        assert_eq!(m.kernels["kernel_kron_pair"].inputs.len(), 1);
    }

    #[test]
    fn missing_keys_error() {
        assert!(Manifest::parse("{}").is_ok()); // empty manifest is valid
        assert!(Manifest::parse(r#"{"variants": {"x": {}}}"#).is_err());
    }
}
