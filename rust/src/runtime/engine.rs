//! PJRT execution engine: loads HLO-text artifacts, caches compiled
//! executables, and runs them with Literal I/O.
//!
//! Start-from: /opt/xla-example/load_hlo — HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax's 64-bit-id protos).

use super::manifest::{Dtype, TensorSpec};
use crate::error::{Error, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A host-side tensor value crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(vec![x], vec![])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(_, s) | Value::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32(d, _) => d.len(),
            Value::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32(d, _) => Ok(d),
            _ => Err(Error::Runtime("value is not f32".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(d, _) => Ok(d),
            _ => Err(Error::Runtime("value is not i32".into())),
        }
    }

    pub fn first_f32(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }

    /// Validate against a manifest tensor spec.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        let dt_ok = matches!(
            (self, spec.dtype),
            (Value::F32(..), Dtype::F32) | (Value::I32(..), Dtype::I32)
        );
        if !dt_ok {
            return Err(Error::Runtime(format!("dtype mismatch for {}", spec.name)));
        }
        if self.shape() != spec.shape.as_slice() {
            return Err(Error::Runtime(format!(
                "shape mismatch for {}: got {:?}, manifest says {:?}",
                spec.name,
                self.shape(),
                spec.shape
            )));
        }
        Ok(())
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32(d, shape) => {
                let l = xla::Literal::vec1(d);
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                l.reshape(&dims)?
            }
            Value::I32(d, shape) => {
                let l = xla::Literal::vec1(d);
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                l.reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.shape()?;
        let dims: Vec<usize> = match &shape {
            xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
            _ => return Err(Error::Runtime("nested tuple output".into())),
        };
        let ty = lit.element_type()?;
        match ty {
            xla::ElementType::F32 => Ok(Value::F32(lit.to_vec::<f32>()?, dims)),
            xla::ElementType::S32 => Ok(Value::I32(lit.to_vec::<i32>()?, dims)),
            other => Err(Error::Runtime(format!("unsupported output type {other:?}"))),
        }
    }
}

/// Compiled-executable cache over a PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Engine {
    /// Create a CPU engine rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        crate::debug!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine {
            client,
            dir: artifacts_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an artifact file (cached by file name).
    pub fn prepare(&self, file: &str) -> Result<()> {
        if self.cache.borrow().contains_key(file) {
            return Ok(());
        }
        let path = self.dir.join(file);
        let t = crate::util::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("bad path {}", path.display())))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        crate::debug!("compiled {} in {:.1}ms", file, t.elapsed_ms());
        self.cache.borrow_mut().insert(file.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with host values; returns the untupled outputs.
    pub fn run(&self, file: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.prepare(file)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let cache = self.cache.borrow();
        let exe = cache.get(file).expect("prepared above");
        let result = exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = tuple.to_tuple()?;
        parts.iter().map(Value::from_literal).collect()
    }

    /// Number of compiled executables held.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_roundtrip_f32() {
        let v = Value::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn value_roundtrip_i32() {
        let v = Value::I32(vec![7, -3], vec![2]);
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn scalar_shape() {
        let v = Value::scalar_f32(0.5);
        assert!(v.shape().is_empty());
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit).unwrap();
        assert_eq!(back.first_f32().unwrap(), 0.5);
    }

    #[test]
    fn spec_check() {
        let spec = TensorSpec { name: "x".into(), shape: vec![2, 2], dtype: Dtype::F32 };
        assert!(Value::F32(vec![0.0; 4], vec![2, 2]).check(&spec).is_ok());
        assert!(Value::F32(vec![0.0; 4], vec![4]).check(&spec).is_err());
        assert!(Value::I32(vec![0; 4], vec![2, 2]).check(&spec).is_err());
    }
}
