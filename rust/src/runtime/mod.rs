//! Runtime: PJRT execution of AOT artifacts (the xla crate), manifest
//! contract, parameter store, and the artifact registry used by the CLI.

mod engine;
pub mod manifest;
mod params;

pub use engine::{Engine, Value};
pub use manifest::{
    Dtype, FunctionInfo, Init, KernelInfo, Manifest, ParamSpec, TensorSpec, VariantInfo,
};
pub use params::ParamStore;

use crate::error::Result;
use crate::util::{fmt_count, Table};
use std::path::Path;

/// Artifact registry: manifest + existence/staleness checks (the
/// `w2k artifacts` subcommand).
pub struct ArtifactRegistry {
    pub manifest: Manifest,
    dir: std::path::PathBuf,
}

impl ArtifactRegistry {
    pub fn open(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest = Manifest::load(dir)?;
        Ok(ArtifactRegistry { manifest, dir: dir.to_path_buf() })
    }

    /// Validate that every file referenced by the manifest exists.
    pub fn missing_files(&self) -> Vec<String> {
        let mut missing = Vec::new();
        for v in self.manifest.variants.values() {
            for f in v.functions.values() {
                if !self.dir.join(&f.file).exists() {
                    missing.push(f.file.clone());
                }
            }
        }
        for k in self.manifest.kernels.values() {
            if !self.dir.join(&k.file).exists() {
                missing.push(k.file.clone());
            }
        }
        missing
    }

    /// Human-readable inventory.
    pub fn describe(&self) -> String {
        let mut t = Table::new(vec![
            "Variant", "Task", "Embedding", "Order/Rank", "Emb #Params", "Total #Params",
            "Functions",
        ])
        .with_title(format!(
            "artifacts at {} (source hash {})",
            self.dir.display(),
            self.manifest.source_hash.get(..12).unwrap_or("?")
        ));
        for (name, v) in &self.manifest.variants {
            t.add_row(vec![
                name.clone(),
                v.task.clone(),
                v.embedding.kind.clone(),
                format!("{}/{}", v.embedding.order, v.embedding.rank),
                fmt_count(v.embedding.num_params as u64),
                fmt_count(v.total_params() as u64),
                v.functions.keys().cloned().collect::<Vec<_>>().join(","),
            ]);
        }
        let mut s = t.render();
        let missing = self.missing_files();
        if missing.is_empty() {
            s.push_str(&format!(
                "\n{} kernel artifacts; all files present.\n",
                self.manifest.kernels.len()
            ));
        } else {
            s.push_str(&format!("\nMISSING files: {missing:?}\n"));
        }
        s
    }
}
