//! Host-side parameter + optimizer state store.
//!
//! The Rust coordinator owns all training state; executables are pure
//! functions (params, m, v, batch…) → (params', m', v', loss). Initialization
//! follows the manifest init specs so Python never has to run at train time.

use super::engine::Value;
use super::manifest::{Init, ParamSpec};
use crate::error::{Error, Result};
use crate::util::Rng;
use std::io::{Read, Write};
use std::path::Path;

/// Parameters plus Adam moments, in manifest order.
#[derive(Debug, Clone)]
pub struct ParamStore {
    specs: Vec<ParamSpec>,
    params: Vec<Vec<f32>>,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// 1-based Adam step count.
    pub step: u64,
}

impl ParamStore {
    /// Initialize from manifest specs with a seeded RNG (one child stream per
    /// tensor, so adding tensors never perturbs earlier ones).
    pub fn init(specs: &[ParamSpec], seed: u64) -> ParamStore {
        let mut root = Rng::new(seed);
        let mut params = Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            let n = spec.num_elements();
            let data = match spec.init {
                Init::Uniform { a } => {
                    let mut rng = root.fork(i as u64);
                    rng.uniform_vec(n, -(a as f32), a as f32)
                }
                Init::Zeros => vec![0.0; n],
                Init::Ones => vec![1.0; n],
            };
            params.push(data);
        }
        let zeros: Vec<Vec<f32>> = specs.iter().map(|s| vec![0.0; s.num_elements()]).collect();
        ParamStore { specs: specs.to_vec(), params, m: zeros.clone(), v: zeros, step: 0 }
    }

    pub fn num_tensors(&self) -> usize {
        self.specs.len()
    }

    pub fn total_params(&self) -> usize {
        self.specs.iter().map(|s| s.num_elements()).sum()
    }

    pub fn specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    /// Borrow one parameter tensor by name.
    pub fn get(&self, name: &str) -> Option<(&ParamSpec, &[f32])> {
        self.specs
            .iter()
            .position(|s| s.name == name)
            .map(|i| (&self.specs[i], self.params[i].as_slice()))
    }

    pub fn set(&mut self, name: &str, data: Vec<f32>) -> Result<()> {
        let i = self
            .specs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| Error::Runtime(format!("no parameter named {name}")))?;
        if data.len() != self.specs[i].num_elements() {
            return Err(Error::Shape(format!("size mismatch for {name}")));
        }
        self.params[i] = data;
        Ok(())
    }

    /// Values for an inference call: params only, manifest order.
    pub fn param_values(&self) -> Vec<Value> {
        self.specs
            .iter()
            .zip(&self.params)
            .map(|(s, d)| Value::F32(d.clone(), s.shape.clone()))
            .collect()
    }

    /// Values for a train call: params, then m, then v.
    pub fn train_values(&self) -> Vec<Value> {
        let mut out = self.param_values();
        for (s, d) in self.specs.iter().zip(&self.m) {
            out.push(Value::F32(d.clone(), s.shape.clone()));
        }
        for (s, d) in self.specs.iter().zip(&self.v) {
            out.push(Value::F32(d.clone(), s.shape.clone()));
        }
        out
    }

    /// Absorb train-step outputs (params', m', v' prefix of the output list)
    /// and bump the step counter.
    pub fn absorb(&mut self, outputs: &[Value]) -> Result<()> {
        let p = self.specs.len();
        if outputs.len() < 3 * p {
            return Err(Error::Runtime(format!(
                "expected >= {} outputs, got {}",
                3 * p,
                outputs.len()
            )));
        }
        for i in 0..p {
            self.params[i] = outputs[i].as_f32()?.to_vec();
            self.m[i] = outputs[p + i].as_f32()?.to_vec();
            self.v[i] = outputs[2 * p + i].as_f32()?.to_vec();
        }
        self.step += 1;
        Ok(())
    }

    // ---- checkpointing ------------------------------------------------------
    //
    // Binary format: magic "W2KC", u32 version, u64 step, u32 tensor count,
    // then per tensor: u32 name len, name bytes, u32 ndim, u64 dims…,
    // f32 data (params, m, v consecutively).

    const MAGIC: &'static [u8; 4] = b"W2KC";
    const VERSION: u32 = 1;

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(Self::MAGIC)?;
        w.write_all(&Self::VERSION.to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.specs.len() as u32).to_le_bytes())?;
        for (i, spec) in self.specs.iter().enumerate() {
            let name = spec.name.as_bytes();
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name)?;
            w.write_all(&(spec.shape.len() as u32).to_le_bytes())?;
            for &d in &spec.shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            for part in [&self.params[i], &self.m[i], &self.v[i]] {
                for &x in part.iter() {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
        Ok(())
    }

    /// Load a checkpoint; tensor names/shapes must match `specs`.
    pub fn load(specs: &[ParamSpec], path: &Path) -> Result<ParamStore> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            return Err(Error::Checkpoint("bad magic".into()));
        }
        let version = read_u32(&mut r)?;
        if version != Self::VERSION {
            return Err(Error::Checkpoint(format!("unsupported version {version}")));
        }
        let step = read_u64(&mut r)?;
        let count = read_u32(&mut r)? as usize;
        if count != specs.len() {
            return Err(Error::Checkpoint(format!(
                "tensor count mismatch: checkpoint {count}, manifest {}",
                specs.len()
            )));
        }
        let mut store = ParamStore::init(specs, 0);
        store.step = step;
        for i in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)
                .map_err(|_| Error::Checkpoint("bad tensor name".into()))?;
            if name != specs[i].name {
                return Err(Error::Checkpoint(format!(
                    "tensor {i} name mismatch: {} vs {}",
                    name, specs[i].name
                )));
            }
            let ndim = read_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut r)? as usize);
            }
            if shape != specs[i].shape {
                return Err(Error::Checkpoint(format!("tensor {name} shape mismatch")));
            }
            let n = specs[i].num_elements();
            store.params[i] = read_f32s(&mut r, n)?;
            store.m[i] = read_f32s(&mut r, n)?;
            store.v[i] = read_f32s(&mut r, n)?;
        }
        Ok(store)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "a".into(), shape: vec![2, 3], init: Init::Uniform { a: 0.5 } },
            ParamSpec { name: "b".into(), shape: vec![4], init: Init::Zeros },
        ]
    }

    #[test]
    fn init_respects_specs() {
        let s = ParamStore::init(&specs(), 42);
        assert_eq!(s.num_tensors(), 2);
        assert_eq!(s.total_params(), 10);
        let (_, a) = s.get("a").unwrap();
        assert!(a.iter().all(|x| x.abs() <= 0.5));
        assert!(a.iter().any(|&x| x != 0.0));
        let (_, b) = s.get("b").unwrap();
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn deterministic_init() {
        let a = ParamStore::init(&specs(), 7);
        let b = ParamStore::init(&specs(), 7);
        assert_eq!(a.get("a").unwrap().1, b.get("a").unwrap().1);
        let c = ParamStore::init(&specs(), 8);
        assert_ne!(a.get("a").unwrap().1, c.get("a").unwrap().1);
    }

    #[test]
    fn train_values_layout() {
        let s = ParamStore::init(&specs(), 1);
        let vals = s.train_values();
        assert_eq!(vals.len(), 6); // 2 params + 2 m + 2 v
        assert_eq!(vals[0].shape(), &[2, 3]);
        assert_eq!(vals[2].as_f32().unwrap(), &[0.0; 6]); // m zeros
    }

    #[test]
    fn absorb_updates_state() {
        let mut s = ParamStore::init(&specs(), 1);
        let outs = vec![
            Value::F32(vec![9.0; 6], vec![2, 3]),
            Value::F32(vec![8.0; 4], vec![4]),
            Value::F32(vec![1.0; 6], vec![2, 3]),
            Value::F32(vec![2.0; 4], vec![4]),
            Value::F32(vec![3.0; 6], vec![2, 3]),
            Value::F32(vec![4.0; 4], vec![4]),
            Value::scalar_f32(0.1),
        ];
        s.absorb(&outs).unwrap();
        assert_eq!(s.get("a").unwrap().1, &[9.0; 6]);
        assert_eq!(s.step, 1);
        assert!(s.absorb(&outs[..2]).is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("w2k_test_ckpt");
        let path = dir.join("s.ckpt");
        let mut s = ParamStore::init(&specs(), 3);
        s.step = 17;
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&specs(), &path).unwrap();
        assert_eq!(loaded.step, 17);
        assert_eq!(loaded.get("a").unwrap().1, s.get("a").unwrap().1);
        assert_eq!(loaded.get("b").unwrap().1, s.get("b").unwrap().1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_rejects_mismatch() {
        let dir = std::env::temp_dir().join("w2k_test_ckpt2");
        let path = dir.join("s.ckpt");
        let s = ParamStore::init(&specs(), 3);
        s.save(&path).unwrap();
        let mut other = specs();
        other[0].shape = vec![3, 2];
        assert!(ParamStore::load(&other, &path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
