//! Benchmark harness substrate (criterion substitute).
//!
//! `cargo bench` runs the `harness = false` binaries in `rust/benches/`;
//! each uses [`BenchRunner`] for warmup + timed iterations with summary
//! statistics, and the table/markdown renderers for paper-vs-measured output.

use crate::util::{fmt_duration, Summary, Timer};
use std::time::Duration;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub p50: Duration,
    pub p99: Duration,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.mean.as_secs_f64())
    }

    pub fn render(&self) -> String {
        let tp = self
            .throughput()
            .map(|t| format!("  {:>12.0} items/s", t))
            .unwrap_or_default();
        format!(
            "{:<44} {:>10} ± {:<10} p99 {:>10}  ({} iters){}",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.stddev),
            fmt_duration(self.p99),
            self.iters,
            tp
        )
    }
}

/// Timed-iteration runner with warmup and a wall-clock budget.
pub struct BenchRunner {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            budget: Duration::from_secs(3),
        }
    }
}

impl BenchRunner {
    /// Quick-profile settings for expensive end-to-end cases.
    pub fn heavy() -> Self {
        BenchRunner {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            budget: Duration::from_secs(10),
        }
    }

    /// Run `f` repeatedly; `f` returns a value that is black-boxed to stop
    /// dead-code elimination.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Summary::new();
        let total = Timer::start();
        let mut iters = 0;
        while iters < self.min_iters
            || (iters < self.max_iters && total.elapsed() < self.budget)
        {
            let t = Timer::start();
            black_box(f());
            samples.add(t.elapsed().as_secs_f64());
            iters += 1;
        }
        BenchResult {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(samples.mean()),
            stddev: Duration::from_secs_f64(samples.stddev()),
            p50: Duration::from_secs_f64(samples.p50()),
            p99: Duration::from_secs_f64(samples.p99()),
            items_per_iter: None,
        }
    }

    /// Run with a throughput denominator.
    pub fn run_throughput<T>(
        &self,
        name: &str,
        items_per_iter: f64,
        f: impl FnMut() -> T,
    ) -> BenchResult {
        let mut r = self.run(name, f);
        r.items_per_iter = Some(items_per_iter);
        r
    }
}

/// Optimization-barrier black box (std::hint::black_box re-export point so
/// benches don't depend on unstable features).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard bench header so every bench binary's output is uniform.
pub fn header(title: &str, paper_claim: &str) {
    println!("\n=== {title} ===");
    if !paper_claim.is_empty() {
        println!("paper: {paper_claim}");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_produces_sane_stats() {
        let r = BenchRunner {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 10,
            budget: Duration::from_millis(200),
        };
        let res = r.run("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(res.iters >= 5);
        assert!(res.mean.as_nanos() > 0);
        assert!(res.p99 >= res.p50);
    }

    #[test]
    fn throughput_computed() {
        let r = BenchRunner {
            warmup_iters: 0,
            min_iters: 3,
            max_iters: 3,
            budget: Duration::from_millis(50),
        };
        let res = r.run_throughput("t", 100.0, || 1 + 1);
        assert!(res.throughput().unwrap() > 0.0);
        assert!(res.render().contains("items/s"));
    }
}
