//! GIGAWORD-style synthetic headline generation (Table 1 workload).
//!
//! Each article is 1–3 clauses of boilerplate news prose; the headline is a
//! deterministic compression of the *first* clause (subject–verb–object,
//! adjectives/dates/locations dropped) — the same abstraction-by-deletion
//! structure GIGAWORD headlines exhibit, and exactly the kind of mapping an
//! attention seq2seq learns. ROUGE against the gold headline then measures
//! how much embedding compression degrades the learned mapping.

use super::{Lexicon, SeqPair, Splits};
use crate::config::CorpusConfig;
use crate::util::Rng;

/// One clause's sampled slots.
struct Clause {
    adj: String,
    subj: String,
    place: String,
    verb_past: String,
    obj: String,
    year: String,
}

fn sample_clause(lex: &Lexicon, rng: &mut Rng) -> Clause {
    Clause {
        adj: rng.choose(&lex.adjectives).clone(),
        subj: rng.choose(&lex.entities).clone(),
        place: rng.choose(&lex.places).clone(),
        verb_past: rng.choose(&lex.verbs_past).clone(),
        obj: rng.choose(&lex.objects).clone(),
        year: rng.choose(&lex.years).clone(),
    }
}

fn render_clause(c: &Clause, rng: &mut Rng) -> Vec<String> {
    // A few surface templates for variety; slots stay in canonical order so
    // the compression rule is learnable.
    let t = rng.below(3);
    let mut toks: Vec<String> = Vec::new();
    match t {
        0 => {
            // "the <adj> <subj> in <place> <verb> the <obj> in <year>"
            for w in ["the", &c.adj, &c.subj, "in", &c.place, &c.verb_past, "the", &c.obj, "in", &c.year] {
                toks.push(w.to_string());
            }
        }
        1 => {
            // "<subj> of <place> <verb> <adj> <obj>"
            for w in [&c.subj as &str, "of", &c.place, &c.verb_past, &c.adj, &c.obj] {
                toks.push(w.to_string());
            }
        }
        _ => {
            // "in <year> the <subj> <verb> the <obj> near <place>"
            for w in ["in", &c.year as &str, "the", &c.subj, &c.verb_past, "the", &c.obj, "near", &c.place] {
                toks.push(w.to_string());
            }
        }
    }
    toks
}

/// Headline rule: subject, verb, object of the first clause.
fn headline(c: &Clause) -> Vec<String> {
    vec![c.subj.clone(), c.verb_past.clone(), c.obj.clone()]
}

/// Generate one (article, headline) pair.
pub fn generate_pair(lex: &Lexicon, rng: &mut Rng) -> SeqPair {
    let n_clauses = rng.range(1, 3);
    let first = sample_clause(lex, rng);
    let mut src = render_clause(&first, rng);
    for _ in 1..n_clauses {
        src.push(rng.choose(&lex.connectors).clone());
        let c = sample_clause(lex, rng);
        src.extend(render_clause(&c, rng));
    }
    src.push(".".into());
    SeqPair { src, tgt: headline(&first) }
}

/// Generate the full corpus with splits.
pub fn generate(cfg: &CorpusConfig, target_vocab: usize) -> Splits<SeqPair> {
    let lex = Lexicon::new(cfg.seed, target_vocab);
    let mut rng = Rng::new(cfg.seed ^ 0x5e9);
    let gen_n = |rng: &mut Rng, n: usize| (0..n).map(|_| generate_pair(&lex, rng)).collect();
    Splits {
        train: gen_n(&mut rng, cfg.train),
        valid: gen_n(&mut rng, cfg.valid),
        test: gen_n(&mut rng, cfg.test),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CorpusConfig {
        CorpusConfig { seed: 42, train: 50, valid: 10, test: 10 }
    }

    #[test]
    fn sizes_and_determinism() {
        let a = generate(&cfg(), 300);
        let b = generate(&cfg(), 300);
        assert_eq!(a.sizes(), (50, 10, 10));
        assert_eq!(a.train[0], b.train[0]);
        assert_eq!(a.test[9], b.test[9]);
    }

    #[test]
    fn headline_tokens_appear_in_article() {
        let s = generate(&cfg(), 300);
        for pair in &s.train {
            for t in &pair.tgt {
                assert!(pair.src.contains(t), "headline token {t} missing from {:?}", pair.src);
            }
        }
    }

    #[test]
    fn headline_is_compression() {
        let s = generate(&cfg(), 300);
        for pair in &s.train {
            assert!(pair.tgt.len() < pair.src.len());
            assert_eq!(pair.tgt.len(), 3);
        }
    }

    #[test]
    fn splits_disjoint_streams() {
        let s = generate(&cfg(), 300);
        // Not a strict guarantee (random collisions possible) but the first
        // examples of each split should differ.
        assert_ne!(s.train[0], s.valid[0]);
        assert_ne!(s.valid[0], s.test[0]);
    }

    #[test]
    fn article_ends_with_period() {
        let s = generate(&cfg(), 300);
        for pair in &s.train {
            assert_eq!(pair.src.last().unwrap(), ".");
        }
    }
}
