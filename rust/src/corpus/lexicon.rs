//! Shared word banks for the synthetic corpora.
//!
//! A [`Lexicon`] deterministically synthesizes open-class word families
//! (entities, locations, verbs with tense forms, adjectives, objects,
//! years) sized so the resulting corpus vocabulary approaches a requested
//! target — letting experiments probe word2ketXS's `t^n ≥ d` padding at
//! different vocabulary scales.

use crate::util::Rng;

/// Deterministic word banks.
#[derive(Debug, Clone)]
pub struct Lexicon {
    pub entities: Vec<String>,
    pub places: Vec<String>,
    pub verbs_past: Vec<String>,
    pub verbs_base: Vec<String>,
    pub adjectives: Vec<String>,
    pub objects: Vec<String>,
    pub years: Vec<String>,
    pub connectors: Vec<String>,
}

// Syllable inventory for pronounceable generated words.
const ONSETS: &[&str] = &[
    "b", "br", "c", "ch", "d", "dr", "f", "fl", "g", "gr", "h", "j", "k", "kl", "l", "m", "n",
    "p", "pr", "qu", "r", "s", "sh", "st", "t", "tr", "v", "w", "z",
];
const NUCLEI: &[&str] = &["a", "e", "i", "o", "u", "ai", "ea", "ou", "io"];
const CODAS: &[&str] = &["", "n", "r", "s", "t", "l", "m", "k", "nd", "st"];

fn syllable(rng: &mut Rng) -> String {
    format!(
        "{}{}{}",
        rng.choose(ONSETS),
        rng.choose(NUCLEI),
        rng.choose(CODAS)
    )
}

/// A pronounceable pseudo-word with 2–3 syllables.
pub fn pseudo_word(rng: &mut Rng) -> String {
    let n = rng.range(2, 3);
    (0..n).map(|_| syllable(rng)).collect()
}

fn unique_words(rng: &mut Rng, count: usize, suffix: &str) -> Vec<String> {
    let mut out = Vec::with_capacity(count);
    let mut seen = std::collections::HashSet::new();
    while out.len() < count {
        let mut w = pseudo_word(rng);
        w.push_str(suffix);
        if seen.insert(w.clone()) {
            out.push(w);
        }
    }
    out
}

impl Lexicon {
    /// Build a lexicon with roughly `target_vocab` distinct surface forms
    /// (including function words and digits added by the generators).
    pub fn new(seed: u64, target_vocab: usize) -> Lexicon {
        let mut rng = Rng::new(seed ^ 0x1e71c0);
        // Allocate the open-class budget across families.
        let open = target_vocab.saturating_sub(64).max(32); // reserve for function words
        let n_ent = (open * 30 / 100).max(8);
        let n_place = (open * 15 / 100).max(6);
        let n_verb = (open * 15 / 100).max(6); // past+base share stems
        let n_adj = (open * 15 / 100).max(6);
        let n_obj = (open * 20 / 100).max(6);
        let n_year = (open * 5 / 100).clamp(4, 120);

        let verb_stems = unique_words(&mut rng, n_verb, "");
        Lexicon {
            entities: unique_words(&mut rng, n_ent, ""),
            places: unique_words(&mut rng, n_place, "ia"),
            verbs_past: verb_stems.iter().map(|s| format!("{s}ed")).collect(),
            verbs_base: verb_stems,
            adjectives: unique_words(&mut rng, n_adj, "ic"),
            objects: unique_words(&mut rng, n_obj, "s"),
            years: (0..n_year).map(|i| format!("{}", 1900 + (i * 7) % 120 + i / 17)).collect(),
            connectors: ["and", "while", "although", "because", "after", "before"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        }
    }

    /// Total distinct open-class surface forms.
    pub fn open_class_size(&self) -> usize {
        self.entities.len()
            + self.places.len()
            + self.verbs_past.len()
            + self.verbs_base.len()
            + self.adjectives.len()
            + self.objects.len()
            + self.years.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a = Lexicon::new(7, 500);
        let b = Lexicon::new(7, 500);
        assert_eq!(a.entities, b.entities);
        assert_eq!(a.verbs_past, b.verbs_past);
    }

    #[test]
    fn different_seed_differs() {
        let a = Lexicon::new(1, 500);
        let b = Lexicon::new(2, 500);
        assert_ne!(a.entities, b.entities);
    }

    #[test]
    fn scales_with_target() {
        let small = Lexicon::new(3, 200);
        let big = Lexicon::new(3, 2000);
        assert!(big.open_class_size() > small.open_class_size() * 3);
        // within a factor of ~2 of the target open-class budget
        assert!(big.open_class_size() > 800 && big.open_class_size() < 4000,
            "open class {}", big.open_class_size());
    }

    #[test]
    fn families_have_expected_shape() {
        let l = Lexicon::new(5, 400);
        assert!(l.verbs_past.iter().all(|v| v.ends_with("ed")));
        assert!(l.places.iter().all(|p| p.ends_with("ia")));
        assert!(l.adjectives.iter().all(|a| a.ends_with("ic")));
        assert_eq!(l.verbs_past.len(), l.verbs_base.len());
        assert!(l.years.iter().all(|y| y.parse::<u32>().is_ok()));
    }

    #[test]
    fn words_unique_within_family() {
        let l = Lexicon::new(9, 1000);
        let mut ents = l.entities.clone();
        ents.sort();
        ents.dedup();
        assert_eq!(ents.len(), l.entities.len());
    }
}
