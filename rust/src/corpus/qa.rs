//! SQuAD-style synthetic extractive QA (Table 3 / Figs 2–3 workload).
//!
//! Each context paragraph states 3–6 facts about generated entities
//! ("<entity> was founded in <year> .", "<entity> is located in <place> ." …);
//! each question asks for one fact's value, and the gold answer is the value's
//! token span inside the context. A reader model (biGRU + span scorers) must
//! associate question words with the right fact — the embedding table is by
//! far the dominant parameter block, matching DrQA's profile in the paper.

use super::{Lexicon, QaExample, Splits};
use crate::config::CorpusConfig;
use crate::util::Rng;

/// Fact families: (statement template, question template).
#[derive(Debug, Clone, Copy, PartialEq)]
enum FactKind {
    FoundedYear,
    Location,
    Product,
    Leader,
}

const KINDS: [FactKind; 4] =
    [FactKind::FoundedYear, FactKind::Location, FactKind::Product, FactKind::Leader];

struct Fact {
    kind: FactKind,
    entity: String,
    value: Vec<String>,
}

fn sample_fact(lex: &Lexicon, entity: &str, kind: FactKind, rng: &mut Rng) -> Fact {
    let value: Vec<String> = match kind {
        FactKind::FoundedYear => vec![rng.choose(&lex.years).clone()],
        FactKind::Location => vec![rng.choose(&lex.places).clone()],
        FactKind::Product => vec![rng.choose(&lex.objects).clone()],
        FactKind::Leader => vec![rng.choose(&lex.entities).clone()],
    };
    Fact { kind, entity: entity.to_string(), value }
}

/// Render a fact as a statement, returning (tokens, value_span).
fn render_fact(f: &Fact) -> (Vec<String>, (usize, usize)) {
    let mut toks: Vec<String> = Vec::new();
    let span;
    match f.kind {
        FactKind::FoundedYear => {
            // "<entity> was founded in <year> ."
            toks.push(f.entity.clone());
            toks.extend(["was", "founded", "in"].map(String::from));
            let s = toks.len();
            toks.extend(f.value.iter().cloned());
            span = (s, toks.len());
            toks.push(".".into());
        }
        FactKind::Location => {
            toks.push(f.entity.clone());
            toks.extend(["is", "located", "in"].map(String::from));
            let s = toks.len();
            toks.extend(f.value.iter().cloned());
            span = (s, toks.len());
            toks.push(".".into());
        }
        FactKind::Product => {
            toks.push(f.entity.clone());
            toks.extend(["makes", "the"].map(String::from));
            let s = toks.len();
            toks.extend(f.value.iter().cloned());
            span = (s, toks.len());
            toks.push(".".into());
        }
        FactKind::Leader => {
            toks.push(f.entity.clone());
            toks.extend(["is", "led", "by"].map(String::from));
            let s = toks.len();
            toks.extend(f.value.iter().cloned());
            span = (s, toks.len());
            toks.push(".".into());
        }
    }
    (toks, span)
}

fn render_question(f: &Fact) -> Vec<String> {
    let mut q: Vec<String> = Vec::new();
    match f.kind {
        FactKind::FoundedYear => {
            q.extend(["when", "was"].map(String::from));
            q.push(f.entity.clone());
            q.push("founded".into());
        }
        FactKind::Location => {
            q.extend(["where", "is"].map(String::from));
            q.push(f.entity.clone());
            q.push("located".into());
        }
        FactKind::Product => {
            q.extend(["what", "does"].map(String::from));
            q.push(f.entity.clone());
            q.push("make".into());
        }
        FactKind::Leader => {
            q.extend(["who", "leads"].map(String::from));
            q.push(f.entity.clone());
        }
    }
    q.push("?".into());
    q
}

/// Generate one context with one question about a random fact in it.
pub fn generate_example(lex: &Lexicon, rng: &mut Rng) -> QaExample {
    let n_facts = rng.range(3, 6);
    // Distinct entities so questions are unambiguous; (entity, kind) pairs
    // must be unique within a context.
    let mut facts: Vec<Fact> = Vec::with_capacity(n_facts);
    let mut used: std::collections::HashSet<(String, u8)> = std::collections::HashSet::new();
    while facts.len() < n_facts {
        let e = rng.choose(&lex.entities).clone();
        let k = KINDS[rng.below(KINDS.len())];
        if used.insert((e.clone(), k as u8)) {
            facts.push(sample_fact(lex, &e, k, rng));
        }
    }
    let mut context: Vec<String> = Vec::new();
    let mut spans: Vec<(usize, usize)> = Vec::new();
    for f in &facts {
        let (toks, (s, e)) = render_fact(f);
        let off = context.len();
        spans.push((off + s, off + e));
        context.extend(toks);
    }
    let qi = rng.below(facts.len());
    let question = render_question(&facts[qi]);
    let span = spans[qi];
    let answers = vec![context[span.0..span.1].to_vec()];
    QaExample { context, question, span, answers }
}

/// Generate the full corpus with splits.
pub fn generate(cfg: &CorpusConfig, target_vocab: usize) -> Splits<QaExample> {
    let lex = Lexicon::new(cfg.seed, target_vocab);
    let mut rng = Rng::new(cfg.seed ^ 0x54a4);
    let gen_n = |rng: &mut Rng, n: usize| (0..n).map(|_| generate_example(&lex, rng)).collect();
    Splits {
        train: gen_n(&mut rng, cfg.train),
        valid: gen_n(&mut rng, cfg.valid),
        test: gen_n(&mut rng, cfg.test),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CorpusConfig {
        CorpusConfig { seed: 5, train: 60, valid: 12, test: 12 }
    }

    #[test]
    fn spans_point_at_answers() {
        let s = generate(&cfg(), 400);
        for ex in s.train.iter().chain(&s.valid).chain(&s.test) {
            assert!(ex.span.1 <= ex.context.len());
            assert!(ex.span.0 < ex.span.1);
            assert_eq!(ex.answer_tokens(), ex.answers[0].as_slice());
        }
    }

    #[test]
    fn questions_reference_context_entity() {
        let s = generate(&cfg(), 400);
        for ex in &s.train {
            // The questioned entity appears in both question and context.
            let ent = ex
                .question
                .iter()
                .find(|t| ex.context.contains(t) && t.len() > 2)
                .cloned();
            assert!(ent.is_some(), "q {:?} ctx {:?}", ex.question, ex.context);
        }
    }

    #[test]
    fn question_ends_with_mark() {
        let s = generate(&cfg(), 400);
        for ex in &s.train {
            assert_eq!(ex.question.last().unwrap(), "?");
        }
    }

    #[test]
    fn answer_types_match_question_words() {
        let s = generate(&cfg(), 400);
        for ex in &s.train {
            let ans = &ex.answers[0][0];
            match ex.question[0].as_str() {
                "when" => assert!(ans.parse::<u32>().is_ok(), "when → year, got {ans}"),
                "where" => assert!(ans.ends_with("ia"), "where → place, got {ans}"),
                "what" => assert!(ans.ends_with('s'), "what → object, got {ans}"),
                "who" => {}
                other => panic!("unexpected question word {other}"),
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(&cfg(), 400);
        let b = generate(&cfg(), 400);
        assert_eq!(a.train[0], b.train[0]);
        assert_eq!(a.test[11], b.test[11]);
    }

    #[test]
    fn contexts_have_multiple_facts() {
        let s = generate(&cfg(), 400);
        for ex in &s.train {
            let periods = ex.context.iter().filter(|t| *t == ".").count();
            assert!((3..=6).contains(&periods), "facts {periods}");
        }
    }
}
