//! Synthetic corpus generators standing in for the paper's datasets.
//!
//! The paper evaluates on GIGAWORD (LDC-licensed), IWSLT2014 DE-EN and
//! SQuAD — none of which are available in this offline environment. Each
//! generator below produces a *learnable* synthetic task that exercises the
//! identical code path (same tokenization, vocabulary handling, seq2seq /
//! reader architectures, same metrics), so the relative comparison between
//! embedding representations — the object of Tables 1–3 — is preserved.
//! See DESIGN.md §2 for the substitution argument.
//!
//! All generators are deterministic in their seed.

mod lexicon;
pub mod qa;
pub mod summarization;
pub mod translation;

pub use lexicon::Lexicon;

/// A source→target example (summarization, translation).
#[derive(Debug, Clone, PartialEq)]
pub struct SeqPair {
    pub src: Vec<String>,
    pub tgt: Vec<String>,
}

/// An extractive-QA example.
#[derive(Debug, Clone, PartialEq)]
pub struct QaExample {
    pub context: Vec<String>,
    pub question: Vec<String>,
    /// Gold answer span [start, end) in context token coordinates.
    pub span: (usize, usize),
    /// Acceptable answer strings (token sequences), SQuAD-style.
    pub answers: Vec<Vec<String>>,
}

impl QaExample {
    pub fn answer_tokens(&self) -> &[String] {
        &self.context[self.span.0..self.span.1]
    }
}

/// Train/valid/test splits of a generated corpus.
#[derive(Debug, Clone)]
pub struct Splits<T> {
    pub train: Vec<T>,
    pub valid: Vec<T>,
    pub test: Vec<T>,
}

impl<T> Splits<T> {
    pub fn sizes(&self) -> (usize, usize, usize) {
        (self.train.len(), self.valid.len(), self.test.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qa_example_span_accessor() {
        let ex = QaExample {
            context: ["the", "year", "1999", "was"].iter().map(|s| s.to_string()).collect(),
            question: vec!["when".into()],
            span: (2, 3),
            answers: vec![vec!["1999".into()]],
        };
        assert_eq!(ex.answer_tokens(), &["1999".to_string()]);
    }
}
