//! IWSLT-style synthetic translation (Table 2 workload).
//!
//! The source language is a deterministic transform of the English target:
//! every content word maps through a bijective lexicon to a pseudo-German
//! surface form (affix morphology), word order moves the verb to the end
//! (V-final, as German subordinate clauses), and articles fuse into a single
//! `da` determiner. A seq2seq must therefore learn (a) a word-for-word
//! mapping — stressing embedding capacity on *both* sides — and (b) a
//! reordering rule — stressing the attention pathway. BLEU against the
//! English reference measures degradation under embedding compression.

use super::{Lexicon, SeqPair, Splits};
use crate::config::CorpusConfig;
use crate::util::rng::splitmix64;
use crate::util::Rng;

/// Deterministic "foreignization" of an English token: stable pseudo-word
/// derived from a hash of the token, with a part-of-speech-ish suffix.
pub fn foreign_form(token: &str, seed: u64) -> String {
    if token.chars().all(|c| !c.is_alphabetic()) {
        return token.to_string(); // punctuation/numbers pass through
    }
    let mut h = seed;
    for b in token.bytes() {
        h = h.wrapping_mul(0x100000001b3).wrapping_add(b as u64);
    }
    let mut state = h;
    const ON: &[&str] = &["b", "d", "f", "g", "k", "l", "m", "n", "r", "s", "sch", "t", "v", "z"];
    const VO: &[&str] = &["a", "e", "i", "o", "u", "au", "ei", "ie"];
    let mut w = String::new();
    for _ in 0..2 {
        w.push_str(ON[(splitmix64(&mut state) % ON.len() as u64) as usize]);
        w.push_str(VO[(splitmix64(&mut state) % VO.len() as u64) as usize]);
    }
    // Suffix cues: verbs get -en, others -e/-ung occasionally.
    if token.ends_with("ed") {
        w.push_str("en");
    } else if splitmix64(&mut state) % 3 == 0 {
        w.push_str("ung");
    } else {
        w.push('e');
    }
    w
}

/// Transform an English sentence into its synthetic-German source rendering.
pub fn to_source(english: &[String], seed: u64) -> Vec<String> {
    let mut out: Vec<String> = Vec::with_capacity(english.len());
    let mut verbs: Vec<String> = Vec::new();
    for t in english {
        if t == "the" || t == "a" || t == "of" {
            // Articles/of fuse to a single determiner.
            if out.last().map(|l: &String| l != "da").unwrap_or(true) {
                out.push("da".to_string());
            }
        } else if t.ends_with("ed") && t.len() > 3 {
            // Verb: foreignize and defer to clause end (V-final).
            verbs.push(foreign_form(t, seed));
        } else if t == "." {
            out.extend(verbs.drain(..));
            out.push(".".to_string());
        } else {
            out.push(foreign_form(t, seed));
        }
    }
    out.extend(verbs.drain(..));
    out
}

/// Generate an English target sentence from the lexicon.
fn english_sentence(lex: &Lexicon, rng: &mut Rng) -> Vec<String> {
    let mut s: Vec<String> = Vec::new();
    // "the <adj> <entity> <verb-past> the <obj> in <place> ."
    s.push("the".into());
    if rng.chance(0.6) {
        s.push(rng.choose(&lex.adjectives).clone());
    }
    s.push(rng.choose(&lex.entities).clone());
    s.push(rng.choose(&lex.verbs_past).clone());
    s.push("the".into());
    s.push(rng.choose(&lex.objects).clone());
    if rng.chance(0.5) {
        s.push("in".into());
        s.push(rng.choose(&lex.places).clone());
    }
    if rng.chance(0.3) {
        s.push("in".into());
        s.push(rng.choose(&lex.years).clone());
    }
    s.push(".".into());
    s
}

/// Generate one (source, target) pair.
pub fn generate_pair(lex: &Lexicon, seed: u64, rng: &mut Rng) -> SeqPair {
    let tgt = english_sentence(lex, rng);
    let src = to_source(&tgt, seed);
    SeqPair { src, tgt }
}

/// Generate the full corpus with splits.
pub fn generate(cfg: &CorpusConfig, target_vocab: usize) -> Splits<SeqPair> {
    let lex = Lexicon::new(cfg.seed, target_vocab);
    let map_seed = cfg.seed ^ 0xd3e1;
    let mut rng = Rng::new(cfg.seed ^ 0x717);
    let gen_n =
        |rng: &mut Rng, n: usize| (0..n).map(|_| generate_pair(&lex, map_seed, rng)).collect();
    Splits {
        train: gen_n(&mut rng, cfg.train),
        valid: gen_n(&mut rng, cfg.valid),
        test: gen_n(&mut rng, cfg.test),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CorpusConfig {
        CorpusConfig { seed: 11, train: 40, valid: 8, test: 8 }
    }

    #[test]
    fn foreign_form_deterministic_and_bijective_ish() {
        assert_eq!(foreign_form("cat", 5), foreign_form("cat", 5));
        assert_ne!(foreign_form("cat", 5), foreign_form("dog", 5));
        assert_ne!(foreign_form("cat", 5), foreign_form("cat", 6));
        // punctuation passes through
        assert_eq!(foreign_form(".", 5), ".");
        assert_eq!(foreign_form("1999", 5), "1999");
    }

    #[test]
    fn verbs_move_to_end() {
        let eng: Vec<String> =
            ["the", "cat", "jumped", "the", "fence", "."].iter().map(|s| s.to_string()).collect();
        let src = to_source(&eng, 3);
        // The verb's foreign form (ends in "en") must be second-to-last,
        // right before the period.
        let v = foreign_form("jumped", 3);
        assert!(v.ends_with("en"));
        assert_eq!(src[src.len() - 2], v);
        assert_eq!(src.last().unwrap(), ".");
    }

    #[test]
    fn articles_fuse_to_da() {
        let eng: Vec<String> = ["the", "cat", "."].iter().map(|s| s.to_string()).collect();
        let src = to_source(&eng, 3);
        assert_eq!(src[0], "da");
        assert_eq!(src.iter().filter(|t| *t == "da").count(), 1);
    }

    #[test]
    fn corpus_shapes() {
        let s = generate(&cfg(), 400);
        assert_eq!(s.sizes(), (40, 8, 8));
        for p in &s.train {
            assert!(p.src.len() >= 3);
            assert!(p.tgt.len() >= 4);
            assert_eq!(p.tgt.last().unwrap(), ".");
        }
    }

    #[test]
    fn source_vocab_disjoint_from_english_content() {
        // Foreign forms shouldn't collide with the English lexicon words.
        let s = generate(&cfg(), 400);
        let lex = Lexicon::new(11, 400);
        for p in s.train.iter().take(10) {
            for t in &p.src {
                if t != "." && t != "da" && !t.chars().next().unwrap().is_ascii_digit() {
                    assert!(!lex.entities.contains(t), "collision {t}");
                }
            }
        }
    }

    #[test]
    fn same_english_word_same_source_word() {
        let s = generate(&cfg(), 400);
        // Collect mapping consistency across examples.
        use std::collections::HashMap;
        let mut map: HashMap<String, String> = HashMap::new();
        for p in &s.train {
            // only check the simple aligned case: last content word before '.'
            if p.tgt.len() >= 2 && p.src.len() >= 2 {
                let eng_obj = &p.tgt[p.tgt.len() - 2];
                if eng_obj.ends_with('s') {
                    let f = foreign_form(eng_obj, 11 ^ 0xd3e1);
                    if let Some(prev) = map.insert(eng_obj.clone(), f.clone()) {
                        assert_eq!(prev, f);
                    }
                }
            }
        }
    }
}
