//! `w2k` — leader entrypoint for the word2ket reproduction.
//!
//! Subcommands: `train`, `eval`, `serve`, `params`, `artifacts`.
//! Run `w2k --help` for details.

use word2ket::cli;
use word2ket::config;
use word2ket::coordinator;
use word2ket::embedding::stats;
use word2ket::runtime::ArtifactRegistry;
use word2ket::util::log::{set_level, Level};

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = cli::app();
    let parsed = match app.parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            // --help lands here with the help text as the message.
            eprintln!("{e}");
            return ExitCode::from(2);
        }
    };
    if parsed.flag("verbose") {
        set_level(Level::Debug);
    }
    let result = match parsed.command.as_str() {
        "train" => cmd_train(&parsed),
        "eval" => cmd_eval(&parsed),
        "serve" => cmd_serve(&parsed),
        "params" => cmd_params(),
        "artifacts" => cmd_artifacts(&parsed),
        other => Err(word2ket::Error::Cli(format!("unhandled command {other}"))),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn load_cfg(parsed: &cli::Parsed) -> word2ket::Result<config::ExperimentConfig> {
    let path = parsed.get("config").map(Path::new);
    let overrides = parsed.get_all("set");
    let mut cfg = config::load_with_overrides(path, &overrides)?;
    if let Some(dir) = parsed.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    Ok(cfg)
}

fn cmd_train(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let cfg = load_cfg(parsed)?;
    let report = coordinator::experiment::run_experiment(&cfg)?;
    println!("{}", report.render());
    Ok(())
}

fn cmd_eval(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let cfg = load_cfg(parsed)?;
    let ckpt = parsed
        .get("checkpoint")
        .ok_or_else(|| word2ket::Error::Cli("--checkpoint is required for eval".into()))?;
    let report = coordinator::experiment::eval_checkpoint(&cfg, Path::new(ckpt))?;
    println!("{}", report.render());
    Ok(())
}

fn cmd_serve(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let mut cfg = load_cfg(parsed)?;
    if let Some(addr) = parsed.get("addr") {
        cfg.server.addr = addr.to_string();
    }
    coordinator::server::serve_blocking(&cfg)
}

fn cmd_params() -> word2ket::Result<()> {
    // Reproduce every #Params / space-saving cell of Tables 1–3.
    print!("{}", stats::render_paper_tables());
    Ok(())
}

fn cmd_artifacts(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let dir = parsed.get("artifacts").unwrap_or("artifacts");
    let reg = ArtifactRegistry::open(Path::new(dir))?;
    println!("{}", reg.describe());
    Ok(())
}
