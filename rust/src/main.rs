//! `w2k` — leader entrypoint for the word2ket reproduction.
//!
//! Subcommands: `train`, `eval`, `serve`, `snapshot`, `params`,
//! `artifacts`. Run `w2k --help` for details.

use word2ket::cli;
use word2ket::cluster;
use word2ket::config;
use word2ket::coordinator;
use word2ket::embedding::{self, stats, EmbeddingStore};
use word2ket::index::{IvfIndex, Scorer};
use word2ket::runtime::ArtifactRegistry;
use word2ket::snapshot::{self, Snapshot, SnapshotStore};
use word2ket::util::log::{set_level, Level};
use word2ket::util::Rng;

use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = cli::app();
    let parsed = match app.parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            // --help lands here with the help text as the message.
            eprintln!("{e}");
            return ExitCode::from(2);
        }
    };
    if parsed.flag("verbose") {
        set_level(Level::Debug);
    }
    let result = match parsed.command.as_str() {
        "train" => cmd_train(&parsed),
        "eval" => cmd_eval(&parsed),
        "serve" => cmd_serve(&parsed),
        "cluster" => cmd_cluster(&parsed),
        "snapshot" => cmd_snapshot(&parsed),
        "params" => cmd_params(),
        "artifacts" => cmd_artifacts(&parsed),
        other => Err(word2ket::Error::Cli(format!("unhandled command {other}"))),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn load_cfg(parsed: &cli::Parsed) -> word2ket::Result<config::ExperimentConfig> {
    let path = parsed.get("config").map(Path::new);
    let overrides = parsed.get_all("set");
    let mut cfg = config::load_with_overrides(path, &overrides)?;
    if let Some(dir) = parsed.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    Ok(cfg)
}

fn cmd_train(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let cfg = load_cfg(parsed)?;
    let report = coordinator::experiment::run_experiment(&cfg)?;
    println!("{}", report.render());
    Ok(())
}

fn cmd_eval(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let cfg = load_cfg(parsed)?;
    let ckpt = parsed
        .get("checkpoint")
        .ok_or_else(|| word2ket::Error::Cli("--checkpoint is required for eval".into()))?;
    let report = coordinator::experiment::eval_checkpoint(&cfg, Path::new(ckpt))?;
    println!("{}", report.render());
    Ok(())
}

fn cmd_serve(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let mut cfg = load_cfg(parsed)?;
    if let Some(addr) = parsed.get("addr") {
        cfg.server.addr = addr.to_string();
    }
    coordinator::server::serve_blocking(&cfg)
}

fn cmd_cluster(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let action = parsed.positionals.first().map(String::as_str).ok_or_else(|| {
        word2ket::Error::Cli("cluster needs an action: route | shard | status".into())
    })?;
    let topo_path = parsed
        .positionals
        .get(1)
        .ok_or_else(|| word2ket::Error::Cli("cluster needs a topology TOML file".into()))?;
    let src = std::fs::read_to_string(topo_path).map_err(|e| {
        word2ket::Error::Config(format!("cannot read topology {topo_path}: {e}"))
    })?;
    let doc = config::TomlDoc::parse(&src)?;
    let topo = cluster::Topology::from_doc(&doc)?;
    let router_cfg = cluster::RouterConfig::from_doc(&doc);
    match action {
        // Run the scatter-gather router tier: N shard servers behind one
        // listener speaking the standard text + binary protocols.
        "route" => {
            let addr = parsed.get("addr").unwrap_or("127.0.0.1:7900");
            cluster::server::serve_blocking(topo, router_cfg, addr)
        }
        // Slice the configured store into per-shard snapshot files each
        // shard server boots from (the topology's vocab is authoritative).
        "shard" => {
            let mut cfg = load_cfg(parsed)?;
            cfg.model.vocab = topo.vocab();
            cfg.validate()?;
            let mut rng = Rng::new(cfg.train.seed);
            let store = embedding::build(
                &cfg.embedding,
                cfg.model.vocab,
                cfg.model.emb_dim,
                &mut rng,
            );
            let out = Path::new(parsed.get("out").unwrap_or("shards"));
            let opts =
                snapshot::SaveOptions { codec: cfg.snapshot.codec, ..Default::default() };
            let saved = cluster::save_shard_snapshots(store.as_ref(), &topo, out, &opts)?;
            println!("sliced {} into {} ({})", store.describe(), topo.describe(), out.display());
            for (s, (path, info)) in saved.iter().enumerate() {
                println!(
                    "  shard {s}: {} ({} bytes, {} sections, {} replicas: {})",
                    path.display(),
                    info.bytes,
                    info.sections,
                    topo.replicas(s).len(),
                    topo.replicas(s).join(", ")
                );
            }
            Ok(())
        }
        // One-shot cluster health + STATS roll-up.
        "status" => {
            let no_probe =
                cluster::RouterConfig { probe_interval: std::time::Duration::ZERO, ..router_cfg };
            let router = cluster::Router::new(topo, no_probe);
            let cs = router.stats();
            println!(
                "cluster: {} — {}/{} replicas healthy, generations {}..{}, {} failovers",
                router.topology().describe(),
                cs.healthy_replicas,
                cs.total_replicas,
                cs.min_generation,
                cs.max_generation,
                cs.failovers
            );
            for r in &cs.replicas {
                match &r.stats {
                    Some(ws) => println!(
                        "  shard {} replica {} [{}]: generation={} served={} p99_us={:.0}",
                        r.shard, r.replica, r.addr, ws.model_generation, ws.served, ws.p99_us
                    ),
                    None => println!(
                        "  shard {} replica {} [{}]: UNREACHABLE",
                        r.shard, r.replica, r.addr
                    ),
                }
            }
            router.shutdown();
            Ok(())
        }
        other => Err(word2ket::Error::Cli(format!(
            "unknown cluster action '{other}' (expected route | shard | status)"
        ))),
    }
}

fn cmd_snapshot(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let action = parsed
        .positionals
        .first()
        .map(String::as_str)
        .ok_or_else(|| word2ket::Error::Cli("snapshot needs an action: save | load | info".into()))?;
    let path_s = parsed
        .positionals
        .get(1)
        .ok_or_else(|| word2ket::Error::Cli("snapshot needs a file path".into()))?;
    let path = Path::new(path_s);
    match action {
        "save" => {
            let cfg = load_cfg(parsed)?;
            let codec = match parsed.get("payload") {
                Some(s) => snapshot::Codec::parse(s)?,
                None => cfg.snapshot.codec,
            };
            let mut rng = Rng::new(cfg.train.seed);
            let store: Arc<dyn embedding::EmbeddingStore> = Arc::from(embedding::build(
                &cfg.embedding,
                cfg.model.vocab,
                cfg.model.emb_dim,
                &mut rng,
            ));
            let opts = snapshot::SaveOptions {
                codec,
                norms: parsed.flag("with-norms"),
                ..Default::default()
            };
            let info = if parsed.flag("with-index")
                && cfg.index.kind == config::IndexKind::Ivf
            {
                // Same deterministic seed as the server, so the embedded
                // index is exactly what a fresh boot would have trained.
                let ivf = IvfIndex::build(
                    Scorer::new(store.clone(), cfg.index.cosine),
                    cfg.index.nlist,
                    cfg.index.nprobe,
                    0x6b6e6e,
                );
                snapshot::save_store_with_index(store.as_ref(), Some(&ivf), path, &opts)?
            } else {
                if parsed.flag("with-index") {
                    eprintln!("note: --with-index requires [index] kind=ivf; saving store only");
                }
                snapshot::save_store(store.as_ref(), path, &opts)?
            };
            if parsed.flag("with-norms") && !info.norms_embedded {
                eprintln!(
                    "note: norms not embedded (lossy payload codecs serve dequantized \
                     rows, so loaders recompute norms)"
                );
            }
            let materialized = (cfg.model.vocab * cfg.model.emb_dim * 4) as f64;
            println!(
                "saved {} ({} sections, {} bytes on disk, {:.1}x smaller than the \
                 materialized f32 table) to {}",
                store.describe(),
                info.sections,
                info.bytes,
                materialized / info.bytes as f64,
                path.display()
            );
            Ok(())
        }
        "info" => {
            let snap = Snapshot::open(path, parsed.flag("mmap"))?;
            println!("{}", snap.describe());
            Ok(())
        }
        "load" => {
            if parsed.flag("mmap") {
                let snap = Arc::new(Snapshot::open(path, true)?);
                let store = SnapshotStore::open(snap)?;
                println!("loaded (mmap, zero-copy) {}", store.describe());
                println!("row 0 head: {:?}", &store.lookup(0)[..store.dim().min(4)]);
            } else {
                let snap = Snapshot::open(path, false)?;
                let store = snapshot::load_store(&snap)?;
                println!("loaded (heap) {}", store.describe());
                println!("row 0 head: {:?}", &store.lookup(0)[..store.dim().min(4)]);
            }
            Ok(())
        }
        other => Err(word2ket::Error::Cli(format!(
            "unknown snapshot action '{other}' (expected save | load | info)"
        ))),
    }
}

fn cmd_params() -> word2ket::Result<()> {
    // Reproduce every #Params / space-saving cell of Tables 1–3.
    print!("{}", stats::render_paper_tables());
    Ok(())
}

fn cmd_artifacts(parsed: &cli::Parsed) -> word2ket::Result<()> {
    let dir = parsed.get("artifacts").unwrap_or("artifacts");
    let reg = ArtifactRegistry::open(Path::new(dir))?;
    println!("{}", reg.describe());
    Ok(())
}
