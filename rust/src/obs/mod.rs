//! Observability plane: log₂-bucket histograms, per-stage request timing,
//! a bounded slow-query ring, and Prometheus-style text exposition.
//!
//! Every latency measurement in the serving stack lands here instead of in
//! unbounded sample vectors: a [`Histogram`] is 64 atomic counters covering
//! `[0, 2^63)` microseconds in power-of-two buckets, so recording is one
//! relaxed `fetch_add`, memory is constant for the life of the server, and
//! per-worker histograms merge by addition. Quantiles (p50/p90/p99/p999)
//! come from linear interpolation inside the bucket holding the target
//! rank, which bounds their error by one bucket width.
//!
//! Request time is attributed to [`Stage`]s — `parse → enqueue →
//! batch_wait → cache/kernel → serialize → flush` on a node, `route →
//! fanout → merge` on the cluster router — each stage costing one
//! `Instant` read at its boundary. The [`Obs`] registry owns the stage
//! histograms plus the end-to-end/request and per-batch histograms, the
//! reactor's loop-iteration and writev-batch-size histograms, snapshot
//! reload durations, the pool queue-depth high-water mark, and the
//! [`SlowLog`] ring of the slowest requests with their stage breakdown.
//!
//! Exposition is `name{label="v"} value` lines in a fixed render order, so
//! two servers in the same state emit byte-identical text regardless of
//! which network driver produced it. The `METRICS` text verb and the
//! binary `OP_METRICS` op both serve the same string; a scrape ends with a
//! `# EOF` terminator line (OpenMetrics style) so line-oriented clients
//! know when the exposition is complete.
//!
//! The [`trace`] submodule adds per-request attribution on top of these
//! aggregates: a sampling, allocation-bounded distributed tracer whose
//! span dumps (`TRACE` / `OP_TRACE`) reuse the same exposition format,
//! and whose slowest observation is linked from `METRICS` via the
//! `w2k_request_us_exemplar` line.

pub mod trace;

pub use trace::{Span, SpanRecord, TraceContext, Tracer};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Number of power-of-two buckets per histogram. Bucket 0 holds exact
/// zeros; bucket `b ≥ 1` holds values in `[2^(b-1), 2^b)`; the last bucket
/// absorbs everything from `2^62` up.
pub const BUCKETS: usize = 64;

/// The quantiles every histogram exposes, as (label, q) pairs.
pub const QUANTILES: [(&str, f64); 4] =
    [("0.5", 0.5), ("0.9", 0.9), ("0.99", 0.99), ("0.999", 0.999)];

/// `[obs]` section of the experiment config.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObsConfig {
    /// Master switch: when false every record call is a single branch and
    /// `METRICS` reports all-zero families.
    pub enable: bool,
    /// Capacity of the slow-query ring (`METRICS?slow`); 0 disables it.
    pub slow_log_len: usize,
    /// Per-stage histograms can be switched off independently of counters
    /// and the end-to-end latency histogram.
    pub stage_histograms: bool,
    /// Head-sampling rate for the distributed tracer, in `[0, 1]`: mint a
    /// root span at the edge for every ⌈1/rate⌉-th request. 0 (the
    /// default) never mints, but propagated trace context is still
    /// honored while the trace ring has capacity.
    pub trace_sample: f64,
    /// Capacity of the completed-span ring (`TRACE` / `OP_TRACE`); 0
    /// disables tracing entirely, including propagated context.
    pub trace_ring_len: usize,
    /// Tail-capture threshold: an unsampled request slower than this many
    /// microseconds (or one that errors) is kept in the trace ring
    /// regardless of `trace_sample`. 0 disables latency tail-capture.
    pub trace_slow_us: u64,
}

impl Default for ObsConfig {
    fn default() -> ObsConfig {
        ObsConfig {
            enable: true,
            slow_log_len: 32,
            stage_histograms: true,
            trace_sample: 0.0,
            trace_ring_len: 64,
            trace_slow_us: 100_000,
        }
    }
}

impl ObsConfig {
    /// Read `[obs]` overrides from a parsed TOML doc (missing keys keep
    /// defaults, like every other config section).
    pub fn from_doc(doc: &crate::config::TomlDoc) -> ObsConfig {
        let d = ObsConfig::default();
        ObsConfig {
            enable: doc.bool_or("obs.enable", d.enable),
            slow_log_len: doc.usize_or("obs.slow_log_len", d.slow_log_len),
            stage_histograms: doc.bool_or("obs.stage_histograms", d.stage_histograms),
            trace_sample: doc.f64_or("obs.trace_sample", d.trace_sample),
            trace_ring_len: doc.usize_or("obs.trace_ring_len", d.trace_ring_len),
            trace_slow_us: doc.usize_or("obs.trace_slow_us", d.trace_slow_us as usize) as u64,
        }
    }
}

/// A stage of the request path. Node-local requests flow through the first
/// seven; the cluster router's scatter-gather path uses the last three.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Decoding one request frame or text line off the socket.
    Parse,
    /// Submitting the job into the worker pool (lock + queue push).
    Enqueue,
    /// Sitting in the shard queue until a worker drains the batch.
    BatchWait,
    /// Hot-row cache bookkeeping (lookup, admission, eviction).
    Cache,
    /// Factored-kernel row reconstruction on a cache miss.
    Kernel,
    /// Materializing response rows and waking the requester.
    Serialize,
    /// Writing response bytes to the socket.
    Flush,
    /// Router: partitioning a request across the shard topology.
    Route,
    /// Router: shard round-trips (scoped threads or multiplexed).
    Fanout,
    /// Router: reassembling shard replies into one response.
    Merge,
}

impl Stage {
    /// Every stage, in render order.
    pub const ALL: [Stage; 10] = [
        Stage::Parse,
        Stage::Enqueue,
        Stage::BatchWait,
        Stage::Cache,
        Stage::Kernel,
        Stage::Serialize,
        Stage::Flush,
        Stage::Route,
        Stage::Fanout,
        Stage::Merge,
    ];

    /// The `stage="..."` label value.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Enqueue => "enqueue",
            Stage::BatchWait => "batch_wait",
            Stage::Cache => "cache",
            Stage::Kernel => "kernel",
            Stage::Serialize => "serialize",
            Stage::Flush => "flush",
            Stage::Route => "route",
            Stage::Fanout => "fanout",
            Stage::Merge => "merge",
        }
    }

    fn idx(self) -> usize {
        self as usize
    }
}

/// Fixed-size log₂-bucket histogram: 64 atomic buckets, lock-free
/// recording, constant memory, mergeable by addition. Values are unitless
/// `u64`s — microseconds for latencies, counts for size distributions.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// Index of the bucket holding `v`: 0 for 0, else `⌊log₂ v⌋ + 1`, capped.
fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive lower bound of bucket `b`.
fn bucket_lo(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        1u64 << (b - 1)
    }
}

/// Exclusive upper bound of bucket `b` (saturating for the last bucket).
fn bucket_hi(b: usize) -> u64 {
    if b == 0 {
        1
    } else if b >= BUCKETS - 1 {
        u64::MAX
    } else {
        1u64 << b
    }
}

/// Width of the bucket that holds `v` — the error bound on any quantile
/// estimate near `v`.
pub fn bucket_width(v: u64) -> u64 {
    let b = bucket_of(v);
    bucket_hi(b) - bucket_lo(b)
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// A fresh all-zero histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one observation. One relaxed `fetch_add` per counter — safe
    /// from any thread, never blocks, never allocates.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded values.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Fold another histogram into this one (bucketwise addition) — how
    /// per-worker histograms aggregate without ever resetting.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = theirs.load(Ordering::Relaxed);
            if v > 0 {
                mine.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
    }

    /// Quantile estimate for `q ∈ [0, 1]` by linear interpolation inside
    /// the bucket containing the target rank; 0 when empty. The estimate
    /// is within one bucket width of the exact order statistic.
    ///
    /// Pinned edge behavior (see the edge-case tests):
    /// - empty histogram → `0.0` for every `q`;
    /// - `q = 0.0` and `q = 1.0` clamp to the first/last recorded rank —
    ///   neither can escape the lowest/highest occupied bucket;
    /// - interpolation uses the *midpoint* rank convention
    ///   (`frac = (rank − seen − ½) / n`), so the estimate is always
    ///   strictly inside `[lo, hi)` of its bucket — a single observation
    ///   yields the bucket midpoint, never the exclusive upper bound;
    /// - a saturated top bucket reports the midpoint of
    ///   `[2^62, u64::MAX)`, the estimate's documented ceiling.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        // 1-based rank of the order statistic we are estimating.
        let rank = (q * total as f64).ceil().clamp(1.0, total as f64);
        let mut seen = 0u64;
        for (b, bucket) in self.buckets.iter().enumerate() {
            let n = bucket.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if (seen + n) as f64 >= rank {
                let lo = bucket_lo(b) as f64;
                let hi = bucket_hi(b) as f64;
                let frac = (rank - seen as f64 - 0.5) / n as f64;
                return lo + frac * (hi - lo).max(0.0);
            }
            seen += n;
        }
        bucket_hi(BUCKETS - 1) as f64
    }

    /// Median estimate.
    pub fn p50(&self) -> f64 {
        self.quantile(0.5)
    }

    /// 99th-percentile estimate.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// One entry in the slow-query ring: the request's end-to-end time plus
/// its per-stage breakdown at the moment it completed.
#[derive(Debug, Clone)]
pub struct SlowEntry {
    /// Which operation ("lookup", "knn").
    pub op: &'static str,
    /// End-to-end microseconds for this request.
    pub total_us: u64,
    /// Stage breakdown, in the order the stages ran.
    pub stages: Vec<(Stage, u64)>,
}

/// Bounded in-memory ring of the top-k slowest requests, kept sorted
/// slowest-first. Admission is screened by a lock-free threshold so the
/// hot path only takes the lock for requests that would actually place.
pub struct SlowLog {
    cap: usize,
    /// Smallest total in a full ring — requests at or below it can skip
    /// the lock entirely. 0 while the ring has room.
    threshold: AtomicU64,
    entries: Mutex<Vec<SlowEntry>>,
}

impl SlowLog {
    /// A ring holding at most `cap` entries (`cap == 0` records nothing).
    pub fn new(cap: usize) -> SlowLog {
        SlowLog { cap, threshold: AtomicU64::new(0), entries: Mutex::new(Vec::new()) }
    }

    /// Offer a completed request; it places only if it beats the current
    /// k-th slowest.
    pub fn offer(&self, entry: SlowEntry) {
        if self.cap == 0 || entry.total_us <= self.threshold.load(Ordering::Relaxed) {
            return;
        }
        let mut entries = self.entries.lock().expect("slow log lock poisoned");
        if entries.len() == self.cap
            && entry.total_us <= entries.last().map_or(0, |e| e.total_us)
        {
            return;
        }
        let at = entries
            .iter()
            .position(|e| e.total_us < entry.total_us)
            .unwrap_or(entries.len());
        entries.insert(at, entry);
        entries.truncate(self.cap);
        if entries.len() == self.cap {
            self.threshold
                .store(entries.last().map_or(0, |e| e.total_us), Ordering::Relaxed);
        }
    }

    /// Snapshot of the ring, slowest first.
    pub fn entries(&self) -> Vec<SlowEntry> {
        self.entries.lock().expect("slow log lock poisoned").clone()
    }
}

/// The metrics registry one server (or router) owns: stage histograms,
/// request/batch/reactor/reload histograms, the pool queue high-water
/// mark, and the slow-query ring. Shared as `Arc<Obs>` across model
/// generations and worker threads, so its series are monotonic for the
/// life of the process — a snapshot RELOAD merges into it, never resets.
pub struct Obs {
    enabled: bool,
    stage_histograms: bool,
    stages: [Histogram; Stage::ALL.len()],
    e2e: Histogram,
    batch: Histogram,
    loop_iter: Histogram,
    writev_batch: Histogram,
    reload: Histogram,
    queue_hwm: AtomicU64,
    slow: SlowLog,
    trace: Tracer,
}

impl Default for Obs {
    fn default() -> Obs {
        Obs::new(&ObsConfig::default())
    }
}

impl Obs {
    /// Build a registry from the `[obs]` config section.
    pub fn new(cfg: &ObsConfig) -> Obs {
        Obs {
            enabled: cfg.enable,
            stage_histograms: cfg.enable && cfg.stage_histograms,
            stages: std::array::from_fn(|_| Histogram::new()),
            e2e: Histogram::new(),
            batch: Histogram::new(),
            loop_iter: Histogram::new(),
            writev_batch: Histogram::new(),
            reload: Histogram::new(),
            queue_hwm: AtomicU64::new(0),
            slow: SlowLog::new(if cfg.enable { cfg.slow_log_len } else { 0 }),
            trace: Tracer::new(cfg),
        }
    }

    /// A registry that records nothing (the `enable = false` fast path).
    pub fn disabled() -> Obs {
        Obs::new(&ObsConfig {
            enable: false,
            slow_log_len: 0,
            stage_histograms: false,
            trace_sample: 0.0,
            trace_ring_len: 0,
            trace_slow_us: 0,
        })
    }

    /// The distributed tracer owned by this registry.
    pub fn tracer(&self) -> &Tracer {
        &self.trace
    }

    /// Whether recording is on at all. Callers wrap their `Instant` reads
    /// in this so a disabled plane costs one branch per stage boundary.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Attribute `d` to a stage of the request path.
    pub fn record_stage(&self, stage: Stage, d: Duration) {
        if self.stage_histograms {
            self.stages[stage.idx()].record(d.as_micros() as u64);
        }
    }

    /// Record one request's end-to-end latency (feeds STATS p50/p99).
    pub fn record_e2e(&self, d: Duration) {
        if self.enabled {
            self.e2e.record(d.as_micros() as u64);
        }
    }

    /// Record one worker batch's in-pool service span (drain → replies
    /// sent) — the interval the cache/kernel/serialize stages partition.
    pub fn record_batch(&self, d: Duration) {
        if self.enabled {
            self.batch.record(d.as_micros() as u64);
        }
    }

    /// Record one reactor event-loop iteration.
    pub fn record_loop_iter(&self, d: Duration) {
        if self.enabled {
            self.loop_iter.record(d.as_micros() as u64);
        }
    }

    /// Record how many iovecs one `writev` flushed.
    pub fn record_writev_batch(&self, iovs: usize) {
        if self.enabled {
            self.writev_batch.record(iovs as u64);
        }
    }

    /// Record one snapshot reload's duration.
    pub fn record_reload(&self, d: Duration) {
        if self.enabled {
            self.reload.record(d.as_micros() as u64);
        }
    }

    /// Raise the pool queue-depth high-water mark.
    pub fn note_queue_depth(&self, depth: usize) {
        if self.enabled {
            self.queue_hwm.fetch_max(depth as u64, Ordering::Relaxed);
        }
    }

    /// Offer a completed request to the slow-query ring.
    pub fn note_slow(&self, op: &'static str, total: Duration, stages: Vec<(Stage, u64)>) {
        if self.enabled {
            self.slow.offer(SlowEntry { op, total_us: total.as_micros() as u64, stages });
        }
    }

    /// The end-to-end request-latency histogram (STATS p50/p99 source).
    pub fn e2e(&self) -> &Histogram {
        &self.e2e
    }

    /// The per-batch service-span histogram.
    pub fn batch(&self) -> &Histogram {
        &self.batch
    }

    /// One stage's histogram (tests and exposition).
    pub fn stage(&self, stage: Stage) -> &Histogram {
        &self.stages[stage.idx()]
    }

    /// Pool queue-depth high-water mark since process start.
    pub fn queue_depth_hwm(&self) -> u64 {
        self.queue_hwm.load(Ordering::Relaxed)
    }

    /// Append this registry's families to `out` in fixed order:
    /// per-stage histograms, request/batch, reactor loop + writev, reload,
    /// then the queue high-water gauge. Callers prepend their own counter
    /// families and append the `# EOF` terminator.
    pub fn render_into(&self, out: &mut String) {
        for s in Stage::ALL {
            render_histogram(
                out,
                "w2k_stage_us",
                &format!("stage=\"{}\"", s.name()),
                &self.stages[s.idx()],
            );
        }
        render_histogram(out, "w2k_request_us", "", &self.e2e);
        self.trace.render_exemplar(out);
        render_histogram(out, "w2k_batch_us", "", &self.batch);
        render_histogram(out, "w2k_reactor_loop_us", "", &self.loop_iter);
        render_histogram(out, "w2k_writev_batch_size", "", &self.writev_batch);
        render_histogram(out, "w2k_reload_us", "", &self.reload);
        out.push_str(&format!("w2k_pool_queue_depth_hwm {}\n", self.queue_depth_hwm()));
    }

    /// Render the slow-query ring (`METRICS?slow`), slowest first, with a
    /// `# EOF` terminator. Rank 0 is the slowest request seen.
    pub fn render_slow(&self) -> String {
        let mut out = String::new();
        for (rank, e) in self.slow.entries().iter().enumerate() {
            out.push_str(&format!(
                "w2k_slow_total_us{{rank=\"{rank}\",op=\"{}\"}} {}\n",
                e.op, e.total_us
            ));
            for (stage, us) in &e.stages {
                out.push_str(&format!(
                    "w2k_slow_stage_us{{rank=\"{rank}\",op=\"{}\",stage=\"{}\"}} {us}\n",
                    e.op,
                    stage.name()
                ));
            }
        }
        out.push_str("# EOF\n");
        out
    }
}

/// Append one histogram family: `<name>_count`, `<name>_sum`, then one
/// quantile line per entry of [`QUANTILES`], all carrying `labels` (which
/// may be empty).
pub fn render_histogram(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    let sep = if labels.is_empty() { "" } else { "," };
    if labels.is_empty() {
        out.push_str(&format!("{name}_count {}\n", h.count()));
        out.push_str(&format!("{name}_sum {}\n", h.sum()));
    } else {
        out.push_str(&format!("{name}_count{{{labels}}} {}\n", h.count()));
        out.push_str(&format!("{name}_sum{{{labels}}} {}\n", h.sum()));
    }
    for (label, q) in QUANTILES {
        out.push_str(&format!(
            "{name}{{{labels}{sep}q=\"{label}\"}} {:.0}\n",
            h.quantile(q)
        ));
    }
}

/// Escape a label *value* for exposition text: `\` becomes `\\`, `"`
/// becomes `\"`, and a newline becomes `\n`, per the Prometheus text
/// format. Apply this to any value that did not come from a fixed
/// vocabulary — snapshot paths, replica addresses, operation tags —
/// before splicing it between quotes; otherwise an adversarial value
/// produces unparseable (or forgeable) exposition lines.
pub fn escape_label_value(raw: &str) -> String {
    let mut out = String::with_capacity(raw.len());
    for c in raw.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Re-label a scraped exposition for the cluster roll-up: inject `labels`
/// (e.g. `shard="0",replica="1"`) into every metric line, dropping comment
/// lines (including the scraped server's `# EOF`). `labels` is spliced in
/// verbatim — callers building it from dynamic values must pass each value
/// through [`escape_label_value`] first.
pub fn relabel_exposition(text: &str, labels: &str) -> String {
    let mut out = String::new();
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match line.find('{') {
            Some(at) => {
                out.push_str(&line[..=at]);
                out.push_str(labels);
                out.push(',');
                out.push_str(&line[at + 1..]);
            }
            None => match line.find(' ') {
                Some(at) => {
                    out.push_str(&line[..at]);
                    out.push('{');
                    out.push_str(labels);
                    out.push('}');
                    out.push_str(&line[at..]);
                }
                None => out.push_str(line),
            },
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Summary;

    #[test]
    fn bucket_mapping_covers_the_line() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        // Every bucket's bounds tile the line: hi(b) == lo(b+1).
        for b in 0..BUCKETS - 1 {
            assert_eq!(bucket_hi(b), bucket_lo(b + 1), "bucket {b}");
            assert_eq!(bucket_of(bucket_lo(b)), b);
        }
    }

    #[test]
    fn quantiles_within_one_bucket_of_exact() {
        // A skewed sample (mostly fast, a heavy tail) — the shape STATS
        // percentiles see in practice.
        let h = Histogram::new();
        let mut exact = Summary::new();
        let mut x = 7u64;
        for i in 0..10_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = if i % 100 == 0 { 5_000 + x % 20_000 } else { 10 + x % 400 };
            h.record(v);
            exact.add(v as f64);
        }
        for (_, q) in QUANTILES {
            let est = h.quantile(q);
            let ex = exact.percentile(q * 100.0);
            let tol = bucket_width(est.max(ex) as u64) as f64;
            assert!(
                (est - ex).abs() <= tol,
                "q={q}: est {est} vs exact {ex} (tol {tol})"
            );
        }
    }

    #[test]
    fn empty_and_single_value_quantiles() {
        let h = Histogram::new();
        // Empty histogram: every quantile is exactly 0.0, including the
        // q = 0.0 / 1.0 extremes.
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
        assert_eq!(h.count(), 0);
        h.record(100);
        assert_eq!(h.count(), 1);
        assert_eq!(h.sum(), 100);
        // One sample in bucket [64,128): every quantile lands inside it.
        for (_, q) in QUANTILES {
            let est = h.quantile(q);
            assert!((64.0..128.0).contains(&est), "q={q}: {est}");
        }
        // Pinned: a single observation interpolates to its bucket's
        // midpoint — rank 1 of 1, frac = (1 − 0 − ½)/1 — for every q,
        // because both extremes clamp to the only rank there is.
        assert_eq!(h.quantile(0.0), 96.0);
        assert_eq!(h.quantile(0.5), 96.0);
        assert_eq!(h.quantile(1.0), 96.0);
    }

    #[test]
    fn quantile_extremes_and_saturated_top_bucket_pinned() {
        // Two samples in different buckets: q = 0.0 clamps to rank 1 and
        // q = 1.0 to rank 2, each interpolating to its own bucket's
        // midpoint — neither extreme can escape the occupied buckets.
        let h = Histogram::new();
        h.record(1); // bucket 1: [1, 2)
        h.record(1_000); // bucket 10: [512, 1024)
        assert_eq!(h.quantile(0.0), 1.5);
        assert_eq!(h.quantile(1.0), 768.0);
        // Out-of-domain q values clamp to the same extremes rather than
        // indexing outside the rank range.
        assert_eq!(h.quantile(-3.0), h.quantile(0.0));
        assert_eq!(h.quantile(7.0), h.quantile(1.0));

        // Saturated top bucket: u64::MAX lands in the final bucket
        // [2^62, u64::MAX), and the estimate is pinned to that bucket's
        // midpoint — the documented ceiling of any quantile estimate.
        let top = Histogram::new();
        top.record(u64::MAX);
        let lo = (1u64 << 62) as f64;
        let hi = u64::MAX as f64;
        let expect = lo + 0.5 * (hi - lo);
        assert_eq!(top.quantile(0.5), expect);
        assert_eq!(top.quantile(1.0), expect);
        assert!(top.quantile(1.0) < hi);
    }

    #[test]
    fn merge_is_bucketwise_addition() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in [1u64, 10, 100] {
            a.record(v);
        }
        for v in [1000u64, 10_000] {
            b.record(v);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.sum(), 11_111);
        assert!(a.quantile(0.999) >= 8192.0);
    }

    #[test]
    fn slow_log_keeps_topk_sorted() {
        let log = SlowLog::new(3);
        for (op, us) in
            [("lookup", 50u64), ("knn", 400), ("lookup", 10), ("lookup", 900), ("knn", 200)]
        {
            log.offer(SlowEntry { op, total_us: us, stages: vec![(Stage::BatchWait, us / 2)] });
        }
        let got: Vec<u64> = log.entries().iter().map(|e| e.total_us).collect();
        assert_eq!(got, vec![900, 400, 200]);
        // Below-threshold offers are screened out without displacing.
        log.offer(SlowEntry { op: "lookup", total_us: 5, stages: vec![] });
        assert_eq!(log.entries().len(), 3);
        // Zero-capacity ring records nothing.
        let none = SlowLog::new(0);
        none.offer(SlowEntry { op: "lookup", total_us: 1, stages: vec![] });
        assert!(none.entries().is_empty());
    }

    #[test]
    fn render_is_deterministic_and_disabled_records_nothing() {
        let a = Obs::new(&ObsConfig::default());
        let b = Obs::new(&ObsConfig::default());
        let (mut ra, mut rb) = (String::new(), String::new());
        a.render_into(&mut ra);
        b.render_into(&mut rb);
        assert_eq!(ra, rb, "two fresh registries must render byte-identically");
        for family in [
            "w2k_stage_us_count{stage=\"parse\"}",
            "w2k_stage_us{stage=\"kernel\",q=\"0.999\"}",
            "w2k_request_us_count",
            "w2k_batch_us_sum",
            "w2k_reactor_loop_us_count",
            "w2k_writev_batch_size_count",
            "w2k_reload_us_count",
            "w2k_pool_queue_depth_hwm",
        ] {
            assert!(ra.contains(family), "missing {family} in:\n{ra}");
        }

        let off = Obs::disabled();
        off.record_stage(Stage::Kernel, Duration::from_micros(10));
        off.record_e2e(Duration::from_micros(10));
        off.record_batch(Duration::from_micros(10));
        off.note_queue_depth(7);
        off.note_slow("lookup", Duration::from_micros(10), vec![]);
        assert_eq!(off.e2e().count(), 0);
        assert_eq!(off.stage(Stage::Kernel).count(), 0);
        assert_eq!(off.queue_depth_hwm(), 0);
        assert_eq!(off.render_slow(), "# EOF\n");
    }

    #[test]
    fn stage_toggle_keeps_e2e_but_drops_stages() {
        let obs = Obs::new(&ObsConfig {
            enable: true,
            slow_log_len: 4,
            stage_histograms: false,
            ..ObsConfig::default()
        });
        obs.record_stage(Stage::Cache, Duration::from_micros(9));
        obs.record_e2e(Duration::from_micros(9));
        assert_eq!(obs.stage(Stage::Cache).count(), 0);
        assert_eq!(obs.e2e().count(), 1);
    }

    #[test]
    fn relabel_injects_into_both_line_shapes() {
        let text = "w2k_served_total 5\nw2k_stage_us{stage=\"parse\",q=\"0.5\"} 12\n# EOF\n";
        let got = relabel_exposition(text, "shard=\"1\",replica=\"0\"");
        assert_eq!(
            got,
            "w2k_served_total{shard=\"1\",replica=\"0\"} 5\n\
             w2k_stage_us{shard=\"1\",replica=\"0\",stage=\"parse\",q=\"0.5\"} 12\n"
        );
    }

    #[test]
    fn adversarial_label_values_escape_cleanly() {
        // Backslashes and quotes — the two characters that break the
        // `name{label="value"} n` grammar — must be escaped before a
        // dynamic value (a snapshot path, a replica address) is spliced
        // between quotes.
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("C:\\snapshots\\v2"), "C:\\\\snapshots\\\\v2");
        assert_eq!(
            escape_label_value("evil\"} 1\nfake_metric 2"),
            "evil\\\"} 1\\nfake_metric 2"
        );
        // An escaped adversarial value survives relabeling with balanced,
        // escaped quotes: every unescaped quote in the output is a label
        // delimiter, so the quote count stays even.
        let hostile = "sn\\ap\"shot";
        let labels = format!("path=\"{}\"", escape_label_value(hostile));
        let out = relabel_exposition("w2k_reloads_total 3\n", &labels);
        assert_eq!(out, "w2k_reloads_total{path=\"sn\\\\ap\\\"shot\"} 3\n");
        let unescaped_quotes = out
            .as_bytes()
            .iter()
            .enumerate()
            .filter(|&(i, &b)| b == b'"' && (i == 0 || out.as_bytes()[i - 1] != b'\\'))
            .count();
        assert_eq!(unescaped_quotes, 2, "{out}");
    }

    #[test]
    fn slow_render_includes_stage_breakdown() {
        let obs = Obs::new(&ObsConfig {
            enable: true,
            slow_log_len: 2,
            stage_histograms: true,
            ..ObsConfig::default()
        });
        obs.note_slow(
            "knn",
            Duration::from_micros(750),
            vec![(Stage::BatchWait, 300), (Stage::Kernel, 400)],
        );
        let text = obs.render_slow();
        assert!(text.contains("w2k_slow_total_us{rank=\"0\",op=\"knn\"} 750"), "{text}");
        assert!(
            text.contains("w2k_slow_stage_us{rank=\"0\",op=\"knn\",stage=\"kernel\"} 400"),
            "{text}"
        );
        assert!(text.ends_with("# EOF\n"), "{text}");
    }

    #[test]
    fn config_defaults_and_doc_overrides() {
        let d = ObsConfig::default();
        assert!(d.enable);
        assert_eq!(d.slow_log_len, 32);
        assert!(d.stage_histograms);
        assert_eq!(d.trace_sample, 0.0, "tracing is off-by-default at the edge");
        assert_eq!(d.trace_ring_len, 64);
        assert_eq!(d.trace_slow_us, 100_000);
        let doc = crate::config::TomlDoc::parse(
            "[obs]\nenable = false\nslow_log_len = 7\nstage_histograms = false\n\
             trace_sample = 0.25\ntrace_ring_len = 16\ntrace_slow_us = 5000\n",
        )
        .unwrap();
        let cfg = ObsConfig::from_doc(&doc);
        assert!(!cfg.enable);
        assert_eq!(cfg.slow_log_len, 7);
        assert!(!cfg.stage_histograms);
        assert_eq!(cfg.trace_sample, 0.25);
        assert_eq!(cfg.trace_ring_len, 16);
        assert_eq!(cfg.trace_slow_us, 5_000);
    }
}
