//! Distributed per-request tracing: sampled, allocation-bounded span
//! capture with wire-propagated context.
//!
//! A trace is a tree of [`SpanRecord`]s sharing one 16-byte trace id. The
//! root span is minted at the edge — the first server that saw the client
//! request — by deterministic head-sampling (every ⌈1/`trace_sample`⌉-th
//! request). When the cluster router fans a sampled request out to shards
//! it propagates a [`TraceContext`] in an optional binary-frame extension,
//! so each shard's `parse → enqueue → batch_wait → cache/kernel →
//! serialize → flush` span parents under the router's `route → fanout →
//! merge` root span.
//!
//! Memory is bounded by construction: completed spans land in a per-node
//! ring of at most `trace_ring_len` records, each carrying a small
//! stage vector; an unsampled request allocates nothing. Tail-capture
//! complements head-sampling — a request that breaches `trace_slow_us` or
//! errors is kept as a minimal root record regardless of the sampling
//! rate, so the ring always contains the requests worth looking at.
//!
//! Dumps (`TRACE <id>` / `OP_TRACE`) reuse the exposition line format of
//! the metrics plane, which means the router can assemble a cross-node
//! trace with the exact same scrape-and-relabel machinery as the METRICS
//! roll-up.

use super::Stage;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Wire-propagated trace context: which trace a request belongs to, and
/// the sender's span id — the parent of any span the receiver creates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceContext {
    /// 16-byte id shared by every span in one request tree.
    pub trace_id: u128,
    /// The sender's span id.
    pub span_id: u64,
}

impl TraceContext {
    /// A trace id as the fixed-width lowercase hex used in `trace="…"`
    /// labels and accepted by the `TRACE <id>` verb.
    pub fn hex(trace_id: u128) -> String {
        format!("{trace_id:032x}")
    }

    /// Parse a trace id from 1–32 hex characters (as printed by
    /// [`TraceContext::hex`]); `None` on anything else.
    pub fn parse_hex(s: &str) -> Option<u128> {
        if s.is_empty() || s.len() > 32 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return None;
        }
        u128::from_str_radix(s, 16).ok()
    }
}

/// A completed span as stored in the trace ring.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// Trace this span belongs to.
    pub trace_id: u128,
    /// This span's own id.
    pub span_id: u64,
    /// Parent span id; 0 marks a root span minted at the edge.
    pub parent_id: u64,
    /// Which operation ("lookup", "knn", …).
    pub op: &'static str,
    /// "ok", a short error tag, or "slow" for tail-captured records.
    pub status: &'static str,
    /// End-to-end microseconds covered by this span.
    pub total_us: u64,
    /// Stage breakdown, in the order the stages ran.
    pub stages: Vec<(Stage, u64)>,
}

/// A live span being measured on this node. Plain owned data — it rides
/// inside a pool job or across a router fan-out and is finished exactly
/// once via [`Tracer::finish`], which pushes it into the bounded ring.
#[derive(Debug)]
pub struct Span {
    ctx: TraceContext,
    parent_id: u64,
    op: &'static str,
    status: &'static str,
    started: Instant,
    /// Microseconds spent before `started` (e.g. frame parse time the
    /// driver measured before the request reached the serving layer).
    pre_us: u64,
    stages: Vec<(Stage, u64)>,
}

impl Span {
    /// This span's ids — what gets propagated downstream on a fan-out.
    pub fn context(&self) -> TraceContext {
        self.ctx
    }

    /// Attribute `us` microseconds to `stage`.
    pub fn stage(&mut self, stage: Stage, us: u64) {
        self.stages.push((stage, us));
    }

    /// Mark the span failed with a short status tag ("range", "timeout").
    pub fn set_status(&mut self, status: &'static str) {
        self.status = status;
    }
}

/// Process-wide id source: a counter mixed through splitmix64, salted
/// with the process id so ids from different test servers in one process
/// (and different nodes on one host) never collide.
static NEXT_RAW: AtomicU64 = AtomicU64::new(1);

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

fn mint_u64() -> u64 {
    let raw = NEXT_RAW.fetch_add(1, Ordering::Relaxed) ^ ((std::process::id() as u64) << 32);
    splitmix64(raw).max(1)
}

fn mint_u128() -> u128 {
    ((mint_u64() as u128) << 64) | mint_u64() as u128
}

/// The per-node tracer: head-sampling decisions, span minting, the
/// bounded completed-span ring, tail-capture, and the e2e exemplar.
/// Owned by [`super::Obs`] and shared wherever the registry is.
pub struct Tracer {
    /// Whether spans are stored at all (`[obs] enable` and a non-zero
    /// `trace_ring_len`). Inactive tracers drop propagated context too.
    active: bool,
    /// Mint a root for every `sample_every`-th edge request; 0 never
    /// mints (propagated context is still honored while active).
    sample_every: u64,
    /// Tail-capture threshold in µs; 0 disables latency tail-capture.
    slow_us: u64,
    cap: usize,
    seq: AtomicU64,
    ring: Mutex<VecDeque<SpanRecord>>,
    /// Slowest traced observation so far (µs) and its trace id — the
    /// exemplar METRICS renders next to the e2e histogram.
    exemplar_us: AtomicU64,
    exemplar_trace: Mutex<u128>,
}

impl Tracer {
    /// Build a tracer from the `[obs]` config section.
    pub fn new(cfg: &super::ObsConfig) -> Tracer {
        let active = cfg.enable && cfg.trace_ring_len > 0;
        let rate = cfg.trace_sample.clamp(0.0, 1.0);
        let sample_every =
            if !active || rate <= 0.0 { 0 } else { (1.0 / rate).round().max(1.0) as u64 };
        Tracer {
            active,
            sample_every,
            slow_us: cfg.trace_slow_us,
            cap: if active { cfg.trace_ring_len } else { 0 },
            seq: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
            exemplar_us: AtomicU64::new(0),
            exemplar_trace: Mutex::new(0),
        }
    }

    /// Whether this node stores spans at all. Distinct from sampling:
    /// an active tracer with `trace_sample = 0` never mints roots but
    /// still honors propagated context and tail-captures.
    pub fn active(&self) -> bool {
        self.active
    }

    /// Edge head-sampling: deterministically mint a root span for every
    /// ⌈1/`trace_sample`⌉-th request; `None` when unsampled.
    pub fn maybe_start_root(&self, op: &'static str) -> Option<Span> {
        if self.sample_every == 0 {
            return None;
        }
        if self.seq.fetch_add(1, Ordering::Relaxed) % self.sample_every != 0 {
            return None;
        }
        Some(new_span(TraceContext { trace_id: mint_u128(), span_id: mint_u64() }, 0, op, 0))
    }

    /// Start a span under a propagated upstream context. Always honored
    /// while the tracer is active — the sampling decision was made at the
    /// edge, this node just records its share of the request.
    pub fn start_child(
        &self,
        parent: TraceContext,
        op: &'static str,
        pre_us: u64,
    ) -> Option<Span> {
        if !self.active {
            return None;
        }
        Some(new_span(
            TraceContext { trace_id: parent.trace_id, span_id: mint_u64() },
            parent.span_id,
            op,
            pre_us,
        ))
    }

    /// Complete a span: its duration is `pre_us` plus the time since it
    /// started, and the record lands in the ring (evicting the oldest
    /// when full).
    pub fn finish(&self, span: Span) {
        let total_us = span.pre_us.saturating_add(span.started.elapsed().as_micros() as u64);
        self.push(SpanRecord {
            trace_id: span.ctx.trace_id,
            span_id: span.ctx.span_id,
            parent_id: span.parent_id,
            op: span.op,
            status: span.status,
            total_us,
            stages: span.stages,
        });
    }

    /// Tail-capture: keep a minimal root record for an *unsampled*
    /// request that breached `trace_slow_us` or errored, regardless of
    /// the head-sampling rate.
    pub fn tail_capture(&self, op: &'static str, total_us: u64, error: bool) {
        if !self.active || (!error && (self.slow_us == 0 || total_us < self.slow_us)) {
            return;
        }
        self.push(SpanRecord {
            trace_id: mint_u128(),
            span_id: mint_u64(),
            parent_id: 0,
            op,
            status: if error { "error" } else { "slow" },
            total_us,
            stages: Vec::new(),
        });
    }

    /// Attribute socket-flush time to an already-finished span. The
    /// blocking driver learns the flush duration only after the response
    /// is written, by which point the span (a child of `ctx`) is in the
    /// ring; its `flush` stage and total are extended in place.
    pub fn note_flush(&self, ctx: TraceContext, flush_us: u64) {
        if !self.active || flush_us == 0 {
            return;
        }
        let mut ring = self.ring.lock().expect("trace ring lock poisoned");
        if let Some(rec) = ring
            .iter_mut()
            .rev()
            .find(|r| r.trace_id == ctx.trace_id && r.parent_id == ctx.span_id)
        {
            rec.stages.push((Stage::Flush, flush_us));
            rec.total_us = rec.total_us.saturating_add(flush_us);
        }
    }

    fn push(&self, rec: SpanRecord) {
        if self.cap == 0 {
            return;
        }
        let prev = self.exemplar_us.fetch_max(rec.total_us, Ordering::Relaxed);
        if rec.total_us > prev {
            *self.exemplar_trace.lock().expect("exemplar lock poisoned") = rec.trace_id;
        }
        let mut ring = self.ring.lock().expect("trace ring lock poisoned");
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(rec);
    }

    /// Append every stored span of `trace_id` (spans plus their stage
    /// lines) to `out`, in completion order.
    pub fn render_trace(&self, trace_id: u128, out: &mut String) {
        let ring = self.ring.lock().expect("trace ring lock poisoned");
        for rec in ring.iter().filter(|r| r.trace_id == trace_id) {
            render_span(out, rec);
        }
    }

    /// Append one summary line per ring record, oldest first — the
    /// `TRACE?slow` listing a client picks trace ids from.
    pub fn render_ring(&self, out: &mut String) {
        let ring = self.ring.lock().expect("trace ring lock poisoned");
        for rec in ring.iter() {
            render_span_line(out, rec);
        }
    }

    /// Append the e2e exemplar line — only once a traced observation has
    /// been recorded, so expositions without traced traffic stay
    /// byte-stable scrape over scrape.
    pub fn render_exemplar(&self, out: &mut String) {
        let us = self.exemplar_us.load(Ordering::Relaxed);
        if us == 0 {
            return;
        }
        let trace = *self.exemplar_trace.lock().expect("exemplar lock poisoned");
        out.push_str(&format!("w2k_request_us_exemplar{{trace=\"{trace:032x}\"}} {us}\n"));
    }
}

fn new_span(ctx: TraceContext, parent_id: u64, op: &'static str, pre_us: u64) -> Span {
    Span { ctx, parent_id, op, status: "ok", started: Instant::now(), pre_us, stages: Vec::new() }
}

fn render_span_line(out: &mut String, r: &SpanRecord) {
    out.push_str(&format!(
        "w2k_trace_span{{trace=\"{:032x}\",span=\"{:016x}\",parent=\"{:016x}\",op=\"{}\",status=\"{}\"}} {}\n",
        r.trace_id,
        r.span_id,
        r.parent_id,
        super::escape_label_value(r.op),
        super::escape_label_value(r.status),
        r.total_us
    ));
}

fn render_span(out: &mut String, r: &SpanRecord) {
    render_span_line(out, r);
    for (stage, us) in &r.stages {
        out.push_str(&format!(
            "w2k_trace_stage{{trace=\"{:032x}\",span=\"{:016x}\",stage=\"{}\"}} {us}\n",
            r.trace_id,
            r.span_id,
            stage.name()
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::super::ObsConfig;
    use super::*;

    fn cfg(sample: f64, ring: usize, slow_us: u64) -> ObsConfig {
        ObsConfig {
            trace_sample: sample,
            trace_ring_len: ring,
            trace_slow_us: slow_us,
            ..ObsConfig::default()
        }
    }

    #[test]
    fn head_sampling_is_deterministic() {
        let every = Tracer::new(&cfg(1.0, 8, 0));
        assert!(every.active());
        for _ in 0..5 {
            assert!(every.maybe_start_root("lookup").is_some());
        }
        let half = Tracer::new(&cfg(0.5, 8, 0));
        let hits = (0..10).filter(|_| half.maybe_start_root("lookup").is_some()).count();
        assert_eq!(hits, 5, "rate 0.5 samples exactly every 2nd request");
        let off = Tracer::new(&cfg(0.0, 8, 0));
        assert!(off.active(), "sample=0 still stores propagated spans");
        assert!(off.maybe_start_root("lookup").is_none());
        let dead = Tracer::new(&cfg(1.0, 0, 0));
        assert!(!dead.active());
        assert!(dead.maybe_start_root("lookup").is_none());
    }

    #[test]
    fn ring_is_bounded_and_evicts_oldest() {
        let t = Tracer::new(&cfg(1.0, 2, 0));
        let mut first_trace = 0u128;
        for i in 0..3 {
            let span = t.maybe_start_root("lookup").expect("sampled");
            if i == 0 {
                first_trace = span.context().trace_id;
            }
            t.finish(span);
        }
        let mut all = String::new();
        t.render_ring(&mut all);
        assert_eq!(all.lines().count(), 2, "ring capped at 2:\n{all}");
        let mut gone = String::new();
        t.render_trace(first_trace, &mut gone);
        assert!(gone.is_empty(), "oldest record evicted");
    }

    #[test]
    fn child_spans_parent_under_propagated_context() {
        let t = Tracer::new(&cfg(0.0, 8, 0));
        let parent = TraceContext { trace_id: 0xabcd, span_id: 77 };
        let mut span = t.start_child(parent, "lookup", 3).expect("active tracer");
        assert_eq!(span.context().trace_id, 0xabcd);
        assert_ne!(span.context().span_id, 77, "child gets its own span id");
        span.stage(Stage::Parse, 3);
        span.stage(Stage::BatchWait, 10);
        let ctx = span.context();
        t.finish(span);
        let mut out = String::new();
        t.render_trace(0xabcd, &mut out);
        assert!(
            out.contains(&format!("span=\"{:016x}\",parent=\"{:016x}\"", ctx.span_id, 77)),
            "{out}"
        );
        assert!(out.contains("stage=\"batch_wait\"} 10"), "{out}");
        // note_flush finds the finished child by its parent context.
        t.note_flush(parent, 5);
        let mut out2 = String::new();
        t.render_trace(0xabcd, &mut out2);
        assert!(out2.contains("stage=\"flush\"} 5"), "{out2}");
    }

    #[test]
    fn tail_capture_keeps_slow_and_errored_requests() {
        let t = Tracer::new(&cfg(0.0, 8, 1_000));
        t.tail_capture("lookup", 500, false); // fast + ok: dropped
        t.tail_capture("lookup", 2_000, false); // breach: kept
        t.tail_capture("knn", 10, true); // error: kept
        let mut out = String::new();
        t.render_ring(&mut out);
        assert_eq!(out.lines().count(), 2, "{out}");
        assert!(out.contains("status=\"slow\"} 2000"), "{out}");
        assert!(out.contains("status=\"error\"} 10"), "{out}");
        // slow_us = 0 disables latency tail-capture but not error capture.
        let t0 = Tracer::new(&cfg(0.0, 8, 0));
        t0.tail_capture("lookup", u64::MAX, false);
        let mut none = String::new();
        t0.render_ring(&mut none);
        assert!(none.is_empty(), "{none}");
    }

    #[test]
    fn exemplar_tracks_the_slowest_traced_observation() {
        let t = Tracer::new(&cfg(0.0, 8, 1));
        let mut out = String::new();
        t.render_exemplar(&mut out);
        assert!(out.is_empty(), "no exemplar before any traced request");
        t.tail_capture("lookup", 40, false);
        t.tail_capture("lookup", 900, false);
        t.tail_capture("lookup", 100, false);
        t.render_exemplar(&mut out);
        assert_eq!(out.lines().count(), 1, "{out}");
        assert!(out.starts_with("w2k_request_us_exemplar{trace=\""), "{out}");
        assert!(out.ends_with("} 900\n"), "{out}");
    }

    #[test]
    fn trace_id_hex_roundtrip() {
        let id = 0x0123_4567_89ab_cdef_0011_2233_4455_6677u128;
        let hex = TraceContext::hex(id);
        assert_eq!(hex.len(), 32);
        assert_eq!(TraceContext::parse_hex(&hex), Some(id));
        assert_eq!(TraceContext::parse_hex("ff"), Some(0xff));
        assert_eq!(TraceContext::parse_hex(""), None);
        assert_eq!(TraceContext::parse_hex("xyz"), None);
        assert_eq!(TraceContext::parse_hex(&"0".repeat(33)), None);
    }

    #[test]
    fn minted_ids_are_unique_and_nonzero() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1_000 {
            let id = mint_u64();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate span id");
        }
    }
}
