//! ROUGE-N and ROUGE-L (Lin, 2004), F-measure variants as reported in the
//! paper's Table 1 (RG-1, RG-2, RG-L).

use std::collections::HashMap;

/// Precision / recall / F1 triple.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RougeScore {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

impl RougeScore {
    fn from_counts(overlap: usize, cand: usize, refr: usize) -> RougeScore {
        let precision = if cand == 0 { 0.0 } else { overlap as f64 / cand as f64 };
        let recall = if refr == 0 { 0.0 } else { overlap as f64 / refr as f64 };
        let f1 = if precision + recall == 0.0 {
            0.0
        } else {
            2.0 * precision * recall / (precision + recall)
        };
        RougeScore { precision, recall, f1 }
    }

    pub fn zero() -> RougeScore {
        RougeScore { precision: 0.0, recall: 0.0, f1: 0.0 }
    }
}

fn ngram_counts<T: std::hash::Hash + Eq + Clone>(
    tokens: &[T],
    n: usize,
) -> HashMap<Vec<T>, usize> {
    let mut map = HashMap::new();
    if tokens.len() < n {
        return map;
    }
    for w in tokens.windows(n) {
        *map.entry(w.to_vec()).or_insert(0) += 1;
    }
    map
}

/// ROUGE-N between one candidate and one reference (clipped n-gram overlap).
pub fn rouge_n<T: std::hash::Hash + Eq + Clone>(
    candidate: &[T],
    reference: &[T],
    n: usize,
) -> RougeScore {
    assert!(n >= 1);
    let cand = ngram_counts(candidate, n);
    let refr = ngram_counts(reference, n);
    let overlap: usize = cand
        .iter()
        .map(|(g, &c)| c.min(refr.get(g).copied().unwrap_or(0)))
        .sum();
    let cand_total: usize = cand.values().sum();
    let ref_total: usize = refr.values().sum();
    RougeScore::from_counts(overlap, cand_total, ref_total)
}

/// Length of the longest common subsequence (O(|a|·|b|) DP, O(min) space).
pub fn lcs_len<T: PartialEq>(a: &[T], b: &[T]) -> usize {
    let (short, long) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    let mut prev = vec![0usize; short.len() + 1];
    let mut cur = vec![0usize; short.len() + 1];
    for x in long {
        for (j, y) in short.iter().enumerate() {
            cur[j + 1] = if x == y {
                prev[j] + 1
            } else {
                prev[j + 1].max(cur[j])
            };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[short.len()]
}

/// ROUGE-L: LCS-based F-measure (β=1, as in the summary-level formulation
/// with a single reference).
pub fn rouge_l<T: PartialEq>(candidate: &[T], reference: &[T]) -> RougeScore {
    let l = lcs_len(candidate, reference);
    RougeScore::from_counts(l, candidate.len(), reference.len())
}

/// Corpus-level macro-average of per-example F1 (Table 1 reports averages
/// over the test set × 100).
pub fn rouge_corpus<T: std::hash::Hash + Eq + Clone>(
    pairs: &[(Vec<T>, Vec<T>)],
    n: usize,
    use_lcs: bool,
) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    let total: f64 = pairs
        .iter()
        .map(|(c, r)| {
            if use_lcs {
                rouge_l(c, r).f1
            } else {
                rouge_n(c, r, n).f1
            }
        })
        .sum();
    100.0 * total / pairs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<&str> {
        s.split_whitespace().collect()
    }

    #[test]
    fn identical_scores_one() {
        let c = toks("the cat sat on the mat");
        let r1 = rouge_n(&c, &c, 1);
        let r2 = rouge_n(&c, &c, 2);
        let rl = rouge_l(&c, &c);
        assert_eq!(r1.f1, 1.0);
        assert_eq!(r2.f1, 1.0);
        assert_eq!(rl.f1, 1.0);
    }

    #[test]
    fn disjoint_scores_zero() {
        let c = toks("aa bb");
        let r = toks("cc dd");
        assert_eq!(rouge_n(&c, &r, 1).f1, 0.0);
        assert_eq!(rouge_l(&c, &r).f1, 0.0);
    }

    #[test]
    fn known_unigram_overlap() {
        // candidate: "the cat", reference: "the cat sat"
        let c = toks("the cat");
        let r = toks("the cat sat");
        let s = rouge_n(&c, &r, 1);
        assert!((s.precision - 1.0).abs() < 1e-12);
        assert!((s.recall - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.f1 - 0.8).abs() < 1e-12);
    }

    #[test]
    fn clipping_repeated_ngrams() {
        // candidate repeats "the" 4×; reference has it twice → clipped to 2.
        let c = toks("the the the the");
        let r = toks("the cat the");
        let s = rouge_n(&c, &r, 1);
        assert!((s.precision - 0.5).abs() < 1e-12); // 2/4
    }

    #[test]
    fn lcs_classic() {
        assert_eq!(lcs_len(&toks("a b c d e"), &toks("a c e")), 3);
        assert_eq!(lcs_len(&toks("x"), &toks("y")), 0);
        assert_eq!(lcs_len::<&str>(&[], &toks("a")), 0);
    }

    #[test]
    fn rouge_l_order_sensitive() {
        let r = toks("the cat sat");
        let good = toks("the cat sat");
        let scrambled = toks("sat cat the");
        assert!(rouge_l(&good, &r).f1 > rouge_l(&scrambled, &r).f1);
        // unigram ROUGE is order-insensitive: identical there
        assert_eq!(rouge_n(&good, &r, 1).f1, rouge_n(&scrambled, &r, 1).f1);
    }

    #[test]
    fn bigram_stricter_than_unigram() {
        let c = toks("the cat sat on a mat");
        let r = toks("a cat sat on the mat");
        assert!(rouge_n(&c, &r, 2).f1 < rouge_n(&c, &r, 1).f1);
    }

    #[test]
    fn corpus_scale_0_100() {
        let pairs = vec![
            (toks("a b").iter().map(|s| s.to_string()).collect::<Vec<_>>(),
             toks("a b").iter().map(|s| s.to_string()).collect::<Vec<_>>()),
            (toks("x").iter().map(|s| s.to_string()).collect::<Vec<_>>(),
             toks("y").iter().map(|s| s.to_string()).collect::<Vec<_>>()),
        ];
        let score = rouge_corpus(&pairs, 1, false);
        assert!((score - 50.0).abs() < 1e-9);
    }

    #[test]
    fn empty_candidate_safe() {
        let c: Vec<&str> = vec![];
        let r = toks("a b");
        assert_eq!(rouge_n(&c, &r, 1).f1, 0.0);
        assert_eq!(rouge_l(&c, &r).f1, 0.0);
    }
}
