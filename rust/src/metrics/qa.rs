//! SQuAD-style answer metrics: exact match and token-level F1
//! (Rajpurkar et al., 2016 evaluation script semantics, over pre-tokenized
//! answers). Used for Table 3 and the Fig. 2 training-dynamics curves.

use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QaScore {
    pub em: f64,
    pub f1: f64,
}

/// Exact token-sequence match (1.0/0.0).
pub fn exact_match<T: PartialEq>(prediction: &[T], gold: &[T]) -> f64 {
    if prediction == gold {
        1.0
    } else {
        0.0
    }
}

/// Token-level F1 with multiset overlap.
pub fn qa_f1<T: std::hash::Hash + Eq + Clone>(prediction: &[T], gold: &[T]) -> f64 {
    if prediction.is_empty() || gold.is_empty() {
        return if prediction.is_empty() && gold.is_empty() { 1.0 } else { 0.0 };
    }
    let mut gold_counts: HashMap<&T, usize> = HashMap::new();
    for t in gold {
        *gold_counts.entry(t).or_insert(0) += 1;
    }
    let mut overlap = 0usize;
    for t in prediction {
        if let Some(c) = gold_counts.get_mut(t) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    if overlap == 0 {
        return 0.0;
    }
    let precision = overlap as f64 / prediction.len() as f64;
    let recall = overlap as f64 / gold.len() as f64;
    2.0 * precision * recall / (precision + recall)
}

/// Against multiple acceptable gold answers, take the best score
/// (the SQuAD convention; Fig. 3's "True Answers" column lists variants).
pub fn qa_best<T: std::hash::Hash + Eq + Clone>(prediction: &[T], golds: &[Vec<T>]) -> QaScore {
    let mut best = QaScore { em: 0.0, f1: 0.0 };
    for g in golds {
        best.em = best.em.max(exact_match(prediction, g));
        best.f1 = best.f1.max(qa_f1(prediction, g));
    }
    best
}

/// Corpus macro-average (×100) over (prediction, acceptable-golds) pairs.
pub fn qa_corpus<T: std::hash::Hash + Eq + Clone>(
    items: &[(Vec<T>, Vec<Vec<T>>)],
) -> QaScore {
    if items.is_empty() {
        return QaScore { em: 0.0, f1: 0.0 };
    }
    let mut em = 0.0;
    let mut f1 = 0.0;
    for (pred, golds) in items {
        let s = qa_best(pred, golds);
        em += s.em;
        f1 += s.f1;
    }
    let n = items.len() as f64;
    QaScore { em: 100.0 * em / n, f1: 100.0 * f1 / n }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<&str> {
        s.split_whitespace().collect()
    }

    #[test]
    fn exact_match_binary() {
        assert_eq!(exact_match(&toks("los angeles times"), &toks("los angeles times")), 1.0);
        assert_eq!(exact_match(&toks("los angeles"), &toks("los angeles times")), 0.0);
    }

    #[test]
    fn f1_partial_overlap() {
        // pred "southern california megaregion" vs gold "the greater southern
        // california megaregion": overlap 3, p=1.0, r=3/5 → f1 = 0.75
        let p = toks("southern california megaregion");
        let g = toks("the greater southern california megaregion");
        assert!((qa_f1(&p, &g) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn f1_multiset_clipping() {
        let p = toks("a a a");
        let g = toks("a b");
        // overlap clipped to 1; p=1/3, r=1/2 → f1 = 0.4
        assert!((qa_f1(&p, &g) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn best_of_multiple_golds() {
        // Fig. 3 example: both "Southern California Megaregion" and "the
        // greater Southern California Megaregion" are acceptable.
        let pred = toks("greater southern california megaregion");
        let golds = vec![
            toks("southern california megaregion"),
            toks("the greater southern california megaregion"),
        ];
        let s = qa_best(&pred, &golds);
        assert!(s.f1 > 0.85);
        assert_eq!(s.em, 0.0);
    }

    #[test]
    fn empty_edge_cases() {
        assert_eq!(qa_f1(&Vec::<&str>::new(), &toks("x")), 0.0);
        assert_eq!(qa_f1(&toks("x"), &Vec::<&str>::new()), 0.0);
        assert_eq!(qa_f1(&Vec::<&str>::new(), &Vec::<&str>::new()), 1.0);
    }

    #[test]
    fn corpus_average_scale() {
        let items = vec![
            (toks("11"), vec![toks("11")]),                  // EM 1, F1 1
            (toks("tijuana"), vec![toks("mexican")]),        // EM 0, F1 0
        ];
        let s = qa_corpus(&items);
        assert!((s.em - 50.0).abs() < 1e-9);
        assert!((s.f1 - 50.0).abs() < 1e-9);
    }
}
