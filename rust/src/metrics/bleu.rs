//! BLEU (Papineni et al., 2002): modified n-gram precision up to 4-grams,
//! geometric mean, brevity penalty. Corpus-level aggregation as used for the
//! paper's IWSLT2014 DE-EN results (Table 2).

use std::collections::HashMap;

/// Detailed BLEU breakdown.
#[derive(Debug, Clone)]
pub struct BleuScore {
    /// 100-scaled BLEU-4.
    pub bleu: f64,
    /// Modified n-gram precisions p_1..p_4.
    pub precisions: [f64; 4],
    pub brevity_penalty: f64,
    pub candidate_len: usize,
    pub reference_len: usize,
}

fn ngrams<T: std::hash::Hash + Eq + Clone>(tokens: &[T], n: usize) -> HashMap<Vec<T>, usize> {
    let mut map = HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *map.entry(w.to_vec()).or_insert(0) += 1;
        }
    }
    map
}

/// Corpus BLEU over (candidate, reference) pairs (single reference each).
pub fn corpus_bleu<T: std::hash::Hash + Eq + Clone>(pairs: &[(Vec<T>, Vec<T>)]) -> BleuScore {
    let mut match_n = [0usize; 4];
    let mut total_n = [0usize; 4];
    let mut cand_len = 0usize;
    let mut ref_len = 0usize;
    for (cand, refr) in pairs {
        cand_len += cand.len();
        ref_len += refr.len();
        for n in 1..=4 {
            let cg = ngrams(cand, n);
            let rg = ngrams(refr, n);
            for (g, &c) in &cg {
                match_n[n - 1] += c.min(rg.get(g).copied().unwrap_or(0));
            }
            total_n[n - 1] += cg.values().sum::<usize>();
        }
    }
    // Precisions with smoothing on higher orders only (n ≥ 2): unigram
    // precision stays exact so fully-disjoint outputs score ~0, while short
    // synthetic sentences with no 4-gram matches don't zero the geometric
    // mean (cf. Lin & Och smoothing "method 1").
    let mut precisions = [0.0f64; 4];
    let mut log_sum = 0.0f64;
    let mut orders = 0usize;
    for n in 0..4 {
        if total_n[n] == 0 {
            // Candidates shorter than n tokens: order n is undefined and is
            // excluded from the geometric mean (effective max order).
            continue;
        }
        let p = if match_n[n] == 0 {
            if n == 0 {
                0.0
            } else {
                1.0 / (2.0 * total_n[n] as f64)
            }
        } else {
            match_n[n] as f64 / total_n[n] as f64
        };
        precisions[n] = p;
        orders += 1;
        log_sum += if p > 0.0 { p.ln() } else { f64::NEG_INFINITY };
    }
    let geo = if orders > 0 && log_sum.is_finite() {
        (log_sum / orders as f64).exp()
    } else {
        0.0
    };
    let bp = if cand_len == 0 {
        0.0
    } else if cand_len > ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / cand_len as f64).exp()
    };
    BleuScore {
        bleu: 100.0 * bp * geo,
        precisions,
        brevity_penalty: bp,
        candidate_len: cand_len,
        reference_len: ref_len,
    }
}

/// Single-sentence BLEU convenience wrapper.
pub fn sentence_bleu<T: std::hash::Hash + Eq + Clone>(cand: &[T], refr: &[T]) -> BleuScore {
    corpus_bleu(&[(cand.to_vec(), refr.to_vec())])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<&str> {
        s.split_whitespace().collect()
    }

    #[test]
    fn perfect_match_is_100() {
        let c = toks("the cat sat on the mat today");
        let s = sentence_bleu(&c, &c);
        assert!((s.bleu - 100.0).abs() < 1e-9, "bleu {}", s.bleu);
        assert_eq!(s.brevity_penalty, 1.0);
        for p in s.precisions {
            assert_eq!(p, 1.0);
        }
    }

    #[test]
    fn disjoint_is_near_zero() {
        let s = sentence_bleu(&toks("aa bb cc dd"), &toks("ww xx yy zz"));
        assert_eq!(s.bleu, 0.0, "bleu {}", s.bleu);
    }

    #[test]
    fn brevity_penalty_applies() {
        // Candidate shorter than reference → BP < 1.
        let c = toks("the cat");
        let r = toks("the cat sat on the mat");
        let s = sentence_bleu(&c, &r);
        assert!(s.brevity_penalty < 1.0);
        assert!((s.brevity_penalty - (1.0f64 - 6.0 / 2.0).exp()).abs() < 1e-12);
    }

    #[test]
    fn longer_candidate_no_penalty() {
        let c = toks("the cat sat on the mat and then some");
        let r = toks("the cat sat");
        let s = sentence_bleu(&c, &r);
        assert_eq!(s.brevity_penalty, 1.0);
        assert!(s.bleu < 100.0); // precision drops instead
    }

    #[test]
    fn known_precision_values() {
        // cand: "the the the", ref: "the cat": p1 = clipped 1/3.
        let s = sentence_bleu(&toks("the the the"), &toks("the cat"));
        assert!((s.precisions[0] - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn corpus_pools_counts() {
        // Corpus BLEU pools n-gram counts rather than averaging sentence BLEU.
        let pairs = vec![
            (toks("a b c d"), toks("a b c d")),
            (toks("e f g h"), toks("e f x h")),
        ];
        let s = corpus_bleu(&pairs);
        assert!(s.bleu > 30.0 && s.bleu < 100.0);
        assert_eq!(s.candidate_len, 8);
        assert_eq!(s.reference_len, 8);
    }

    #[test]
    fn order_matters_via_higher_ngrams() {
        let r = toks("a b c d e f");
        let inorder = sentence_bleu(&toks("a b c d e f"), &r);
        let shuffled = sentence_bleu(&toks("f e d c b a"), &r);
        assert!(inorder.bleu > shuffled.bleu);
    }

    #[test]
    fn empty_candidate_is_zero() {
        let s = sentence_bleu(&Vec::<&str>::new(), &toks("a b"));
        assert_eq!(s.bleu, 0.0);
    }
}
