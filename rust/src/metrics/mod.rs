//! Evaluation metrics used by the paper's three tasks: ROUGE-1/2/L
//! (GIGAWORD, Table 1), corpus BLEU (IWSLT, Table 2), and SQuAD-style
//! EM / token-F1 (Table 3, Fig. 2). Plus perplexity for training logs.

mod bleu;
mod qa;
mod rouge;

pub use bleu::{corpus_bleu, sentence_bleu, BleuScore};
pub use qa::{exact_match, qa_best, qa_corpus, qa_f1, QaScore};
pub use rouge::{lcs_len, rouge_corpus, rouge_l, rouge_n, RougeScore};

/// Perplexity from mean cross-entropy (nats).
pub fn perplexity(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

#[cfg(test)]
mod tests {
    #[test]
    fn perplexity_of_zero_loss_is_one() {
        assert!((super::perplexity(0.0) - 1.0).abs() < 1e-12);
        assert!(super::perplexity(2.0) > 7.0);
    }
}
