//! Runtime-dispatched SIMD kernels for the factored hot paths.
//!
//! Every arithmetic-dense routine in the serving stack (row reconstruction
//! in `repr/kernels.rs`, the §2.3 factored inner product, BruteForce/IVF
//! scans) funnels through four primitives: [`dot`], [`axpy`], [`add_assign`]
//! and [`kron2_accumulate`]. This module provides scalar, SSE2 and AVX2
//! implementations of each, selected once per process by runtime CPU-feature
//! detection (`is_x86_feature_detected!`) and overridable via the `W2K_SIMD`
//! environment variable (`scalar` | `sse2` | `avx2` | `auto`; requests above
//! what the CPU supports are clamped down).
//!
//! # Bit-parity contract
//!
//! All levels produce **bit-identical** results for identical inputs, so a
//! server's wire surface does not depend on the CPU it happens to run on —
//! the same goldens-prove-it contract the interpreter-vs-AOT snippets pin,
//! applied to kernels. Two rules make this hold:
//!
//! * **Pinned association order.** `dot` accumulates in a fixed 8-lane shape
//!   at every level: lane `l` holds the sequential sum of `a[c*8+l] *
//!   b[c*8+l]` over full 8-element chunks, the lanes reduce as `m[j] =
//!   lane[j] + lane[j+4]` followed by `(m[0] + m[2]) + (m[1] + m[3])`, and
//!   the tail (`len % 8` elements) is added sequentially onto that sum. This
//!   is exactly the order a single 8-wide AVX2 accumulator (or an SSE2 lo/hi
//!   accumulator pair) reduces in, and the scalar fallback replays it lane
//!   by lane. `axpy`, `add_assign` and `kron2_accumulate` are elementwise
//!   (each output cell is one `mul` + `add` of the same operands at every
//!   level), so any vector width produces the same bits by construction.
//! * **No FMA in parity-bound arithmetic.** A fused multiply-add rounds once
//!   where `mul` + `add` round twice, so fusing would change bits between
//!   levels. The top level is still *gated* on `avx2 && fma` (and named
//!   `avx2+fma`) so future non-parity-bound kernels — e.g. quantized-domain
//!   scoring — may assume FMA is present, but the four primitives here use
//!   explicit mul/add intrinsics, which the compiler never contracts.
//!
//! A consequence worth documenting: `kron2_accumulate` is *dense*. The old
//! scalar kernel skipped zero coefficients as a throughput trick; a vector
//! kernel cannot cheaply do the same, and skipping changes bits in `-0.0`
//! and `NaN` corners (`acc + 0.0 * b` is not always `acc`). Dense semantics
//! keep every level identical.
//!
//! Goldens plus randomized property tests (lengths 0..64 and large lengths
//! with tail remainders 1–7) enforce the contract in `cargo test`, and a
//! forced `W2K_SIMD=scalar` CI leg keeps the portable fallback from rotting.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[cfg(target_arch = "x86_64")]
mod x86;

/// A kernel set, ordered weakest-to-strongest so requested levels can be
/// clamped to what the CPU supports with `min`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SimdLevel {
    /// Portable scalar fallback (any architecture).
    Scalar = 0,
    /// 128-bit SSE2 kernels (x86_64 baseline, always available there).
    Sse2 = 1,
    /// 256-bit AVX2 kernels; the level is gated on `avx2 && fma` even
    /// though the parity-bound kernels use explicit mul/add (see module
    /// docs for why FMA itself is excluded).
    Avx2Fma = 2,
}

impl SimdLevel {
    /// Human-readable kernel-set name (used in logs, METRICS and README).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2Fma => "avx2+fma",
        }
    }

    /// Numeric code carried by the STATS `simd_level` field
    /// (0 = scalar, 1 = sse2, 2 = avx2+fma).
    pub fn code(self) -> u8 {
        self as u8
    }

    fn from_code(v: u8) -> SimdLevel {
        match v {
            2 => SimdLevel::Avx2Fma,
            1 => SimdLevel::Sse2,
            _ => SimdLevel::Scalar,
        }
    }
}

/// Strongest kernel set this CPU can run (ignores the `W2K_SIMD` override).
#[cfg(target_arch = "x86_64")]
pub fn detect() -> SimdLevel {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        SimdLevel::Avx2Fma
    } else {
        // SSE2 is part of the x86_64 ABI baseline.
        SimdLevel::Sse2
    }
}

/// Strongest kernel set this CPU can run (ignores the `W2K_SIMD` override).
#[cfg(not(target_arch = "x86_64"))]
pub fn detect() -> SimdLevel {
    SimdLevel::Scalar
}

/// Every level this CPU can execute, weakest first. Parity tests iterate
/// this so they exercise exactly the sets that can run here.
pub fn available_levels() -> Vec<SimdLevel> {
    let top = detect();
    [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2Fma]
        .into_iter()
        .filter(|&l| l <= top)
        .collect()
}

/// Parse a `W2K_SIMD` value. `None` means "auto": use [`detect`].
pub fn parse_level(s: &str) -> Option<SimdLevel> {
    match s.to_ascii_lowercase().as_str() {
        "scalar" => Some(SimdLevel::Scalar),
        "sse2" => Some(SimdLevel::Sse2),
        "avx2" | "avx2+fma" | "avx2fma" => Some(SimdLevel::Avx2Fma),
        _ => None,
    }
}

const LEVEL_UNSET: u8 = u8::MAX;

/// Cached active level; `LEVEL_UNSET` until the first [`level`] call.
static ACTIVE: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

/// Serializes [`with_level`] callers (benches, byte-identity tests) so a
/// temporary override cannot be clobbered by a concurrent one. Regular
/// readers never touch this lock — and because of the bit-parity contract,
/// reading a temporarily overridden level is harmless anyway.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

/// The active kernel set for this process. Resolved once on first use:
/// `W2K_SIMD` if set to a recognized name (clamped to [`detect`]),
/// otherwise whatever the CPU supports.
pub fn level() -> SimdLevel {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v != LEVEL_UNSET {
        return SimdLevel::from_code(v);
    }
    let l = std::env::var("W2K_SIMD")
        .ok()
        .and_then(|s| parse_level(&s))
        .unwrap_or_else(detect)
        .min(detect());
    ACTIVE.store(l.code(), Ordering::Relaxed);
    l
}

/// Force the active kernel set for this process, clamped to what the CPU
/// supports; returns the level actually installed. Intended for benches and
/// parity tests — servers pick once at startup via [`level`]. Prefer
/// [`with_level`], which restores the previous level when done.
pub fn set_level(l: SimdLevel) -> SimdLevel {
    let l = l.min(detect());
    ACTIVE.store(l.code(), Ordering::Relaxed);
    l
}

/// Run `f` with the active level forced to `l` (clamped to the CPU), then
/// restore the previous level — including on panic. Callers are serialized
/// on a process-wide lock so overrides never interleave.
pub fn with_level<R>(l: SimdLevel, f: impl FnOnce() -> R) -> R {
    struct Restore(SimdLevel);
    impl Drop for Restore {
        fn drop(&mut self) {
            set_level(self.0);
        }
    }
    let _serial = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = Restore(level());
    set_level(l);
    f()
}

// ---------------------------------------------------------------------------
// Dispatched kernels.
//
// Each public kernel has a `*_at` twin taking an explicit level (clamped to
// the CPU, so it is always safe to call); the plain form reads the cached
// process level. Slices shorter than one vector chunk take an inlined
// sequential path that is bit-identical to every level's tail handling —
// this keeps tiny leaf dots (order-4 geometries have length-4 leaves) from
// paying an atomic load plus an uninlinable `#[target_feature]` call.
// ---------------------------------------------------------------------------

/// Inner product in the pinned 8-lane association order (see module docs).
/// Pairs beyond the shorter slice are ignored.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    if n < 8 {
        // All-tail: every level computes the same sequential sum from +0.0.
        let mut s = 0.0f32;
        for (&x, &y) in a[..n].iter().zip(&b[..n]) {
            s += x * y;
        }
        return s;
    }
    dot_dispatch(level(), a, b)
}

/// [`dot`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn dot_at(l: SimdLevel, a: &[f32], b: &[f32]) -> f32 {
    dot_dispatch(l.min(detect()), a, b)
}

/// `y[i] += alpha * x[i]` over the shorter of the two slices.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    if x.len().min(y.len()) < 8 {
        scalar::axpy(alpha, x, y);
        return;
    }
    axpy_dispatch(level(), alpha, x, y)
}

/// [`axpy`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn axpy_at(l: SimdLevel, alpha: f32, x: &[f32], y: &mut [f32]) {
    axpy_dispatch(l.min(detect()), alpha, x, y)
}

/// `acc[i] += src[i]` over the shorter of the two slices.
#[inline]
pub fn add_assign(acc: &mut [f32], src: &[f32]) {
    if acc.len().min(src.len()) < 8 {
        scalar::add_assign(acc, src);
        return;
    }
    add_assign_dispatch(level(), acc, src)
}

/// [`add_assign`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn add_assign_at(l: SimdLevel, acc: &mut [f32], src: &[f32]) {
    add_assign_dispatch(l.min(detect()), acc, src)
}

/// Dense blocked outer-product accumulation: treats `acc` as consecutive
/// blocks of `b.len()` and adds `a[i] * b` into block `i`.
///
/// Hardened against geometry mismatches from untrusted (snapshot-loaded)
/// factors: the block count is clamped to `a.len()`, so an `acc` longer
/// than `a.len() * b.len()` leaves its uncovered suffix untouched instead
/// of panicking, and a short `acc` truncates the final block.
#[inline]
pub fn kron2_accumulate(a: &[f32], b: &[f32], acc: &mut [f32]) {
    kron2_dispatch(level(), a, b, acc)
}

/// [`kron2_accumulate`] at an explicit level (clamped to the CPU).
#[inline]
pub fn kron2_accumulate_at(l: SimdLevel, a: &[f32], b: &[f32], acc: &mut [f32]) {
    kron2_dispatch(l.min(detect()), a, b, acc)
}

// The dispatchers require `l <= detect()`: both call sites above guarantee
// it (the cached level is stored clamped; `*_at` clamps explicitly), which
// is what makes the `unsafe` target-feature calls sound.

#[inline]
fn dot_dispatch(l: SimdLevel, a: &[f32], b: &[f32]) -> f32 {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Sse2 => unsafe { x86::dot_sse2(a, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdLevel::Avx2Fma => unsafe { x86::dot_avx2(a, b) },
        _ => scalar::dot(a, b),
    }
}

#[inline]
fn axpy_dispatch(l: SimdLevel, alpha: f32, x: &[f32], y: &mut [f32]) {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Sse2 => unsafe { x86::axpy_sse2(alpha, x, y) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdLevel::Avx2Fma => unsafe { x86::axpy_avx2(alpha, x, y) },
        _ => scalar::axpy(alpha, x, y),
    }
}

#[inline]
fn add_assign_dispatch(l: SimdLevel, acc: &mut [f32], src: &[f32]) {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Sse2 => unsafe { x86::add_assign_sse2(acc, src) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdLevel::Avx2Fma => unsafe { x86::add_assign_avx2(acc, src) },
        _ => scalar::add_assign(acc, src),
    }
}

#[inline]
fn kron2_dispatch(l: SimdLevel, a: &[f32], b: &[f32], acc: &mut [f32]) {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Sse2 => unsafe { x86::kron2_sse2(a, b, acc) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdLevel::Avx2Fma => unsafe { x86::kron2_avx2(a, b, acc) },
        _ => scalar::kron2_accumulate(a, b, acc),
    }
}

/// Portable reference kernels. These *define* the canonical bits; the
/// vector implementations must match them exactly (proved by the parity
/// tests below).
mod scalar {
    /// Canonical dot: 8 sequential lanes, the pinned two-stage reduction,
    /// then a sequential tail (see module docs for the exact order).
    pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut lanes = [0.0f32; 8];
        let ca = a[..n].chunks_exact(8);
        let cb = b[..n].chunks_exact(8);
        let (ta, tb) = (ca.remainder(), cb.remainder());
        for (xs, ys) in ca.zip(cb) {
            for ((lane, &x), &y) in lanes.iter_mut().zip(xs).zip(ys) {
                *lane += x * y;
            }
        }
        let m = [
            lanes[0] + lanes[4],
            lanes[1] + lanes[5],
            lanes[2] + lanes[6],
            lanes[3] + lanes[7],
        ];
        let mut s = (m[0] + m[2]) + (m[1] + m[3]);
        for (&x, &y) in ta.iter().zip(tb) {
            s += x * y;
        }
        s
    }

    pub(super) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        for (o, &v) in y.iter_mut().zip(x) {
            *o += alpha * v;
        }
    }

    pub(super) fn add_assign(acc: &mut [f32], src: &[f32]) {
        for (o, &v) in acc.iter_mut().zip(src) {
            *o += v;
        }
    }

    /// Canonical dense kron2: block count clamped to `a.len()`, final block
    /// truncated to `acc`, no zero skipping (see module docs).
    pub(super) fn kron2_accumulate(a: &[f32], b: &[f32], acc: &mut [f32]) {
        let q = b.len();
        if q == 0 {
            return;
        }
        let blocks = a.len().min(acc.len().div_ceil(q));
        for (i, &x) in a[..blocks].iter().enumerate() {
            let end = ((i + 1) * q).min(acc.len());
            axpy(x, b, &mut acc[i * q..end]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny deterministic generator; magnitudes vary across ~2^16 so sums
    /// actually round and association order is observable.
    struct Rng(u64);

    impl Rng {
        fn next_f32(&mut self) -> f32 {
            self.0 ^= self.0 << 13;
            self.0 ^= self.0 >> 7;
            self.0 ^= self.0 << 17;
            let unit = (self.0 >> 40) as f32 / (1u64 << 24) as f32 - 0.5;
            let scale = match self.0 & 3 {
                0 => 1.0e-3,
                1 => 1.0,
                2 => 64.0,
                _ => 4096.0,
            };
            unit * scale
        }

        fn vec(&mut self, n: usize) -> Vec<f32> {
            (0..n).map(|_| self.next_f32()).collect()
        }
    }

    /// The documented association order, written out naively. This is the
    /// golden: every level must reproduce these bits exactly.
    fn pinned_order_dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let mut lanes = [0.0f32; 8];
        for c in 0..chunks {
            for (l, lane) in lanes.iter_mut().enumerate() {
                *lane += a[c * 8 + l] * b[c * 8 + l];
            }
        }
        let m: Vec<f32> = (0..4).map(|j| lanes[j] + lanes[j + 4]).collect();
        let mut s = (m[0] + m[2]) + (m[1] + m[3]);
        for k in chunks * 8..n {
            s += a[k] * b[k];
        }
        s
    }

    fn test_lengths() -> Vec<usize> {
        // 0..64 catches every lane/tail combination at least eight times;
        // the large ones catch unaligned tails (remainders 1..=7) after
        // many full chunks.
        let mut lens: Vec<usize> = (0..=64).collect();
        lens.extend([1021, 1024, 1031, 2051, 4093, 8199]);
        lens
    }

    #[test]
    fn dot_matches_pinned_association_golden() {
        let mut rng = Rng(0x5eed_0001);
        for n in test_lengths() {
            let a = rng.vec(n);
            let b = rng.vec(n);
            let want = pinned_order_dot(&a, &b);
            for l in available_levels() {
                let got = dot_at(l, &a, &b);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "dot level={:?} n={} got={} want={}",
                    l,
                    n,
                    got,
                    want
                );
            }
            // The cached-level entry point must agree too.
            assert_eq!(dot(&a, &b).to_bits(), want.to_bits(), "dot() n={}", n);
        }
    }

    #[test]
    fn axpy_and_add_assign_parity_across_levels() {
        let mut rng = Rng(0x5eed_0002);
        for n in test_lengths() {
            let x = rng.vec(n);
            let base = rng.vec(n);
            let alpha = rng.next_f32();

            let mut want_axpy = base.clone();
            axpy_at(SimdLevel::Scalar, alpha, &x, &mut want_axpy);
            let mut want_add = base.clone();
            add_assign_at(SimdLevel::Scalar, &mut want_add, &x);

            for l in available_levels() {
                let mut got = base.clone();
                axpy_at(l, alpha, &x, &mut got);
                for (i, (g, w)) in got.iter().zip(&want_axpy).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "axpy level={:?} n={} i={}", l, n, i);
                }
                let mut got = base.clone();
                add_assign_at(l, &mut got, &x);
                for (i, (g, w)) in got.iter().zip(&want_add).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "add level={:?} n={} i={}", l, n, i);
                }
            }
        }
    }

    #[test]
    fn kron2_parity_across_levels_and_geometries() {
        let mut rng = Rng(0x5eed_0003);
        // (p, q, acc_len): exact fits, truncated finals, oversized accs
        // (the hardening clamp), the q == 4 fast path with even and odd
        // block counts, and degenerate shapes.
        let cases = [
            (0, 4, 8),
            (3, 0, 9),
            (1, 1, 1),
            (2, 3, 6),
            (2, 3, 5),
            (2, 3, 10),
            (7, 4, 28),
            (8, 4, 32),
            (8, 4, 30),
            (5, 4, 40),
            (3, 16, 48),
            (3, 16, 41),
            (4, 19, 76),
            (2, 257, 514),
        ];
        for &(p, q, acc_len) in &cases {
            let a = rng.vec(p);
            let b = rng.vec(q);
            let base = rng.vec(acc_len);

            let mut want = base.clone();
            kron2_accumulate_at(SimdLevel::Scalar, &a, &b, &mut want);
            for l in available_levels() {
                let mut got = base.clone();
                kron2_accumulate_at(l, &a, &b, &mut got);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "kron2 level={:?} p={} q={} acc={} i={}",
                        l,
                        p,
                        q,
                        acc_len,
                        i
                    );
                }
            }
        }
    }

    #[test]
    fn kron2_clamps_instead_of_panicking_on_short_factor() {
        // Regression: acc longer than a.len() * b.len() used to index `a`
        // out of bounds. The covered prefix accumulates; the rest is
        // untouched.
        let a = [2.0f32, 3.0];
        let b = [1.0f32, 10.0, 100.0];
        for l in available_levels() {
            let mut acc = vec![0.5f32; 10];
            kron2_accumulate_at(l, &a, &b, &mut acc);
            assert_eq!(
                &acc[..6],
                &[2.5, 20.5, 200.5, 3.5, 30.5, 300.5],
                "level={:?}",
                l
            );
            assert!(acc[6..].iter().all(|&v| v == 0.5), "level={:?}", l);
        }
    }

    #[test]
    fn kron2_is_dense_in_signed_zero_corners() {
        // 0.0 * b must still be *added* (a zero-skip would leave -0.0 in
        // place; adding +0.0 * 1.0 flips it to +0.0).
        for l in available_levels() {
            let mut acc = [-0.0f32; 2];
            kron2_accumulate_at(l, &[0.0], &[1.0, 1.0], &mut acc);
            assert_eq!(acc[0].to_bits(), 0.0f32.to_bits(), "level={:?}", l);
            assert_eq!(acc[1].to_bits(), 0.0f32.to_bits(), "level={:?}", l);
        }
    }

    #[test]
    fn parse_level_names() {
        assert_eq!(parse_level("scalar"), Some(SimdLevel::Scalar));
        assert_eq!(parse_level("SSE2"), Some(SimdLevel::Sse2));
        assert_eq!(parse_level("avx2"), Some(SimdLevel::Avx2Fma));
        assert_eq!(parse_level("avx2+fma"), Some(SimdLevel::Avx2Fma));
        assert_eq!(parse_level("auto"), None);
        assert_eq!(parse_level(""), None);
        assert_eq!(parse_level("neon"), None);
    }

    #[test]
    fn with_level_forces_and_clamps() {
        with_level(SimdLevel::Scalar, || {
            assert_eq!(level(), SimdLevel::Scalar);
        });
        // Requests above the CPU's ceiling clamp instead of lying.
        with_level(SimdLevel::Avx2Fma, || {
            assert!(level() <= detect());
        });
    }

    #[test]
    fn level_codes_and_names_are_stable() {
        assert_eq!(SimdLevel::Scalar.code(), 0);
        assert_eq!(SimdLevel::Sse2.code(), 1);
        assert_eq!(SimdLevel::Avx2Fma.code(), 2);
        assert_eq!(SimdLevel::Avx2Fma.name(), "avx2+fma");
        for l in available_levels() {
            assert_eq!(SimdLevel::from_code(l.code()), l);
        }
    }
}
