//! Runtime-dispatched SIMD kernels for the factored hot paths.
//!
//! Every arithmetic-dense routine in the serving stack (row reconstruction
//! in `repr/kernels.rs`, the §2.3 factored inner product, BruteForce/IVF
//! scans) funnels through four primitives: [`dot`], [`axpy`], [`add_assign`]
//! and [`kron2_accumulate`]. This module provides scalar, SSE2 and AVX2
//! implementations of each, selected once per process by runtime CPU-feature
//! detection (`is_x86_feature_detected!`) and overridable via the `W2K_SIMD`
//! environment variable (`scalar` | `sse2` | `avx2` | `auto`; requests above
//! what the CPU supports are clamped down).
//!
//! # Bit-parity contract
//!
//! All levels produce **bit-identical** results for identical inputs, so a
//! server's wire surface does not depend on the CPU it happens to run on —
//! the same goldens-prove-it contract the interpreter-vs-AOT snippets pin,
//! applied to kernels. Two rules make this hold:
//!
//! * **Pinned association order.** `dot` accumulates in a fixed 8-lane shape
//!   at every level: lane `l` holds the sequential sum of `a[c*8+l] *
//!   b[c*8+l]` over full 8-element chunks, the lanes reduce as `m[j] =
//!   lane[j] + lane[j+4]` followed by `(m[0] + m[2]) + (m[1] + m[3])`, and
//!   the tail (`len % 8` elements) is added sequentially onto that sum. This
//!   is exactly the order a single 8-wide AVX2 accumulator (or an SSE2 lo/hi
//!   accumulator pair) reduces in, and the scalar fallback replays it lane
//!   by lane. `axpy`, `add_assign` and `kron2_accumulate` are elementwise
//!   (each output cell is one `mul` + `add` of the same operands at every
//!   level), so any vector width produces the same bits by construction.
//! * **No FMA in parity-bound arithmetic.** A fused multiply-add rounds once
//!   where `mul` + `add` round twice, so fusing would change bits between
//!   levels. The top level is still *gated* on `avx2 && fma` (and named
//!   `avx2+fma`) so future non-parity-bound kernels — e.g. quantized-domain
//!   scoring — may assume FMA is present, but the four primitives here use
//!   explicit mul/add intrinsics, which the compiler never contracts.
//!
//! A consequence worth documenting: `kron2_accumulate` is *dense*. The old
//! scalar kernel skipped zero coefficients as a throughput trick; a vector
//! kernel cannot cheaply do the same, and skipping changes bits in `-0.0`
//! and `NaN` corners (`acc + 0.0 * b` is not always `acc`). Dense semantics
//! keep every level identical.
//!
//! Goldens plus randomized property tests (lengths 0..64 and large lengths
//! with tail remainders 1–7) enforce the contract in `cargo test`, and a
//! forced `W2K_SIMD=scalar` CI leg keeps the portable fallback from rotting.
//!
//! # Quantized-domain integer kernels
//!
//! The `quant/` subsystem scores bit-packed leaves without dequantizing, via
//! four integer primitives: [`idot_b1`] (sign bits: XNOR/popcount),
//! [`idot_b2`], [`idot_i4`] and [`idot_i8`] (packed 2/4/8-bit codes:
//! widen-multiply-accumulate). They dispatch through the same level
//! machinery, but their parity story is *stronger* than the float kernels':
//! the accumulation is exact `i32` arithmetic, so **any** summation order
//! yields identical bits and every level agrees with the scalar definition
//! by construction. Goldens below still pin the scalar definition so the
//! code semantics (LSB-first packing, centered code values) cannot drift.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[cfg(target_arch = "x86_64")]
mod x86;

/// A kernel set, ordered weakest-to-strongest so requested levels can be
/// clamped to what the CPU supports with `min`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SimdLevel {
    /// Portable scalar fallback (any architecture).
    Scalar = 0,
    /// 128-bit SSE2 kernels (x86_64 baseline, always available there).
    Sse2 = 1,
    /// 256-bit AVX2 kernels; the level is gated on `avx2 && fma` even
    /// though the parity-bound kernels use explicit mul/add (see module
    /// docs for why FMA itself is excluded).
    Avx2Fma = 2,
}

impl SimdLevel {
    /// Human-readable kernel-set name (used in logs, METRICS and README).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2Fma => "avx2+fma",
        }
    }

    /// Numeric code carried by the STATS `simd_level` field
    /// (0 = scalar, 1 = sse2, 2 = avx2+fma).
    pub fn code(self) -> u8 {
        self as u8
    }

    fn from_code(v: u8) -> SimdLevel {
        match v {
            2 => SimdLevel::Avx2Fma,
            1 => SimdLevel::Sse2,
            _ => SimdLevel::Scalar,
        }
    }
}

/// Strongest kernel set this CPU can run (ignores the `W2K_SIMD` override).
#[cfg(target_arch = "x86_64")]
pub fn detect() -> SimdLevel {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        SimdLevel::Avx2Fma
    } else {
        // SSE2 is part of the x86_64 ABI baseline.
        SimdLevel::Sse2
    }
}

/// Strongest kernel set this CPU can run (ignores the `W2K_SIMD` override).
#[cfg(not(target_arch = "x86_64"))]
pub fn detect() -> SimdLevel {
    SimdLevel::Scalar
}

/// Every level this CPU can execute, weakest first. Parity tests iterate
/// this so they exercise exactly the sets that can run here.
pub fn available_levels() -> Vec<SimdLevel> {
    let top = detect();
    [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2Fma]
        .into_iter()
        .filter(|&l| l <= top)
        .collect()
}

/// Parse a `W2K_SIMD` value. `None` means "auto": use [`detect`].
pub fn parse_level(s: &str) -> Option<SimdLevel> {
    match s.to_ascii_lowercase().as_str() {
        "scalar" => Some(SimdLevel::Scalar),
        "sse2" => Some(SimdLevel::Sse2),
        "avx2" | "avx2+fma" | "avx2fma" => Some(SimdLevel::Avx2Fma),
        _ => None,
    }
}

const LEVEL_UNSET: u8 = u8::MAX;

/// Cached active level; `LEVEL_UNSET` until the first [`level`] call.
static ACTIVE: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

/// Serializes [`with_level`] callers (benches, byte-identity tests) so a
/// temporary override cannot be clobbered by a concurrent one. Regular
/// readers never touch this lock — and because of the bit-parity contract,
/// reading a temporarily overridden level is harmless anyway.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

/// The active kernel set for this process. Resolved once on first use:
/// `W2K_SIMD` if set to a recognized name (clamped to [`detect`]),
/// otherwise whatever the CPU supports.
pub fn level() -> SimdLevel {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v != LEVEL_UNSET {
        return SimdLevel::from_code(v);
    }
    let l = std::env::var("W2K_SIMD")
        .ok()
        .and_then(|s| parse_level(&s))
        .unwrap_or_else(detect)
        .min(detect());
    ACTIVE.store(l.code(), Ordering::Relaxed);
    l
}

/// Force the active kernel set for this process, clamped to what the CPU
/// supports; returns the level actually installed. Intended for benches and
/// parity tests — servers pick once at startup via [`level`]. Prefer
/// [`with_level`], which restores the previous level when done.
pub fn set_level(l: SimdLevel) -> SimdLevel {
    let l = l.min(detect());
    ACTIVE.store(l.code(), Ordering::Relaxed);
    l
}

/// Run `f` with the active level forced to `l` (clamped to the CPU), then
/// restore the previous level — including on panic. Callers are serialized
/// on a process-wide lock so overrides never interleave.
pub fn with_level<R>(l: SimdLevel, f: impl FnOnce() -> R) -> R {
    struct Restore(SimdLevel);
    impl Drop for Restore {
        fn drop(&mut self) {
            set_level(self.0);
        }
    }
    let _serial = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = Restore(level());
    set_level(l);
    f()
}

// ---------------------------------------------------------------------------
// Dispatched kernels.
//
// Each public kernel has a `*_at` twin taking an explicit level (clamped to
// the CPU, so it is always safe to call); the plain form reads the cached
// process level. Slices shorter than one vector chunk take an inlined
// sequential path that is bit-identical to every level's tail handling —
// this keeps tiny leaf dots (order-4 geometries have length-4 leaves) from
// paying an atomic load plus an uninlinable `#[target_feature]` call.
// ---------------------------------------------------------------------------

/// Inner product in the pinned 8-lane association order (see module docs).
/// Pairs beyond the shorter slice are ignored.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    if n < 8 {
        // All-tail: every level computes the same sequential sum from +0.0.
        let mut s = 0.0f32;
        for (&x, &y) in a[..n].iter().zip(&b[..n]) {
            s += x * y;
        }
        return s;
    }
    dot_dispatch(level(), a, b)
}

/// [`dot`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn dot_at(l: SimdLevel, a: &[f32], b: &[f32]) -> f32 {
    dot_dispatch(l.min(detect()), a, b)
}

/// `y[i] += alpha * x[i]` over the shorter of the two slices.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    if x.len().min(y.len()) < 8 {
        scalar::axpy(alpha, x, y);
        return;
    }
    axpy_dispatch(level(), alpha, x, y)
}

/// [`axpy`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn axpy_at(l: SimdLevel, alpha: f32, x: &[f32], y: &mut [f32]) {
    axpy_dispatch(l.min(detect()), alpha, x, y)
}

/// `acc[i] += src[i]` over the shorter of the two slices.
#[inline]
pub fn add_assign(acc: &mut [f32], src: &[f32]) {
    if acc.len().min(src.len()) < 8 {
        scalar::add_assign(acc, src);
        return;
    }
    add_assign_dispatch(level(), acc, src)
}

/// [`add_assign`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn add_assign_at(l: SimdLevel, acc: &mut [f32], src: &[f32]) {
    add_assign_dispatch(l.min(detect()), acc, src)
}

/// Dense blocked outer-product accumulation: treats `acc` as consecutive
/// blocks of `b.len()` and adds `a[i] * b` into block `i`.
///
/// Hardened against geometry mismatches from untrusted (snapshot-loaded)
/// factors: the block count is clamped to `a.len()`, so an `acc` longer
/// than `a.len() * b.len()` leaves its uncovered suffix untouched instead
/// of panicking, and a short `acc` truncates the final block.
#[inline]
pub fn kron2_accumulate(a: &[f32], b: &[f32], acc: &mut [f32]) {
    kron2_dispatch(level(), a, b, acc)
}

/// [`kron2_accumulate`] at an explicit level (clamped to the CPU).
#[inline]
pub fn kron2_accumulate_at(l: SimdLevel, a: &[f32], b: &[f32], acc: &mut [f32]) {
    kron2_dispatch(l.min(detect()), a, b, acc)
}

// ---------------------------------------------------------------------------
// Quantized-domain integer dot kernels.
//
// Inputs are LSB-first bit-packed code words as produced by
// `quant::encode_leaf`: code `i` of a `bits`-wide payload occupies bits
// `(i % (32/bits)) * bits ..` of word `i / (32/bits)`. Padding bits past
// code `q-1` in the final word must be zero for `idot_b1` (it popcounts
// whole words); the sub-byte/byte kernels never read past code `q-1`.
// Results are exact i32 sums of centered code products; callers multiply by
// the two per-leaf scales to recover the approximate f32 dot. The caller
// must keep `q <= 65536` so the i8 worst case (127² per code) cannot
// overflow the i32 accumulator — `quant` enforces this at construction.
// ---------------------------------------------------------------------------

/// Sign-bit dot: `q - 2·popcount(a XOR b)` over the packed prefix of `q`
/// bits — each agreeing bit contributes `+1`, each disagreeing bit `-1`
/// (codes are `2u - 1 ∈ {-1, +1}`).
#[inline]
pub fn idot_b1(a: &[u32], b: &[u32], q: usize) -> i32 {
    idot_b1_dispatch(level(), a, b, q)
}

/// [`idot_b1`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn idot_b1_at(l: SimdLevel, a: &[u32], b: &[u32], q: usize) -> i32 {
    idot_b1_dispatch(l.min(detect()), a, b, q)
}

/// 2-bit code dot: `Σ (2·ua-3)(2·ub-3)` over `q` packed codes
/// (codes decode to `{-3, -1, +1, +3}`).
#[inline]
pub fn idot_b2(a: &[u32], b: &[u32], q: usize) -> i32 {
    idot_b2_dispatch(level(), a, b, q)
}

/// [`idot_b2`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn idot_b2_at(l: SimdLevel, a: &[u32], b: &[u32], q: usize) -> i32 {
    idot_b2_dispatch(l.min(detect()), a, b, q)
}

/// 4-bit code dot: `Σ (ua-7)(ub-7)` over `q` packed codes
/// (codes decode to `-7..=7`).
#[inline]
pub fn idot_i4(a: &[u32], b: &[u32], q: usize) -> i32 {
    idot_i4_dispatch(level(), a, b, q)
}

/// [`idot_i4`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn idot_i4_at(l: SimdLevel, a: &[u32], b: &[u32], q: usize) -> i32 {
    idot_i4_dispatch(l.min(detect()), a, b, q)
}

/// 8-bit code dot: `Σ (ua-127)(ub-127)` over `q` packed codes
/// (codes decode to `-127..=127`).
///
/// Codes must lie in `0..=254` — the encoder's range. Byte value 255 is
/// outside the contract: the vector paths compute `u - 127` in wrapping
/// `i8`, which maps 255 to `-128` where the scalar definition says `+128`.
#[inline]
pub fn idot_i8(a: &[u32], b: &[u32], q: usize) -> i32 {
    idot_i8_dispatch(level(), a, b, q)
}

/// [`idot_i8`] at an explicit level (clamped to what the CPU supports).
#[inline]
pub fn idot_i8_at(l: SimdLevel, a: &[u32], b: &[u32], q: usize) -> i32 {
    idot_i8_dispatch(l.min(detect()), a, b, q)
}

// The dispatchers require `l <= detect()`: both call sites above guarantee
// it (the cached level is stored clamped; `*_at` clamps explicitly), which
// is what makes the `unsafe` target-feature calls sound.

#[inline]
fn dot_dispatch(l: SimdLevel, a: &[f32], b: &[f32]) -> f32 {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Sse2 => unsafe { x86::dot_sse2(a, b) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdLevel::Avx2Fma => unsafe { x86::dot_avx2(a, b) },
        _ => scalar::dot(a, b),
    }
}

#[inline]
fn axpy_dispatch(l: SimdLevel, alpha: f32, x: &[f32], y: &mut [f32]) {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Sse2 => unsafe { x86::axpy_sse2(alpha, x, y) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdLevel::Avx2Fma => unsafe { x86::axpy_avx2(alpha, x, y) },
        _ => scalar::axpy(alpha, x, y),
    }
}

#[inline]
fn add_assign_dispatch(l: SimdLevel, acc: &mut [f32], src: &[f32]) {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Sse2 => unsafe { x86::add_assign_sse2(acc, src) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdLevel::Avx2Fma => unsafe { x86::add_assign_avx2(acc, src) },
        _ => scalar::add_assign(acc, src),
    }
}

#[inline]
fn kron2_dispatch(l: SimdLevel, a: &[f32], b: &[f32], acc: &mut [f32]) {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Sse2 => unsafe { x86::kron2_sse2(a, b, acc) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdLevel::Avx2Fma => unsafe { x86::kron2_avx2(a, b, acc) },
        _ => scalar::kron2_accumulate(a, b, acc),
    }
}

// The SSE2 rows below fall back to the scalar definition for b1/b2/i4: the
// byte-shuffle tricks the vector popcount and nibble/crumb unpacks rely on
// need SSSE3+, which is above the x86_64 baseline SSE2 guarantees. Only i8
// has a genuine SSE2 path (unpack + arithmetic-shift sign extension +
// `pmaddwd`). Results are identical either way — integer sums are exact.

#[inline]
fn idot_b1_dispatch(l: SimdLevel, a: &[u32], b: &[u32], q: usize) -> i32 {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Avx2Fma => unsafe { x86::idot_b1_avx2(a, b, q) },
        _ => scalar::idot_b1(a, b, q),
    }
}

#[inline]
fn idot_b2_dispatch(l: SimdLevel, a: &[u32], b: &[u32], q: usize) -> i32 {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Avx2Fma => unsafe { x86::idot_b2_avx2(a, b, q) },
        _ => scalar::idot_b2(a, b, q),
    }
}

#[inline]
fn idot_i4_dispatch(l: SimdLevel, a: &[u32], b: &[u32], q: usize) -> i32 {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Avx2Fma => unsafe { x86::idot_i4_avx2(a, b, q) },
        _ => scalar::idot_i4(a, b, q),
    }
}

#[inline]
fn idot_i8_dispatch(l: SimdLevel, a: &[u32], b: &[u32], q: usize) -> i32 {
    match l {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `l <= detect()`, so the required CPU features are present.
        SimdLevel::Sse2 => unsafe { x86::idot_i8_sse2(a, b, q) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above.
        SimdLevel::Avx2Fma => unsafe { x86::idot_i8_avx2(a, b, q) },
        _ => scalar::idot_i8(a, b, q),
    }
}

/// Portable reference kernels. These *define* the canonical bits; the
/// vector implementations must match them exactly (proved by the parity
/// tests below).
mod scalar {
    /// Canonical dot: 8 sequential lanes, the pinned two-stage reduction,
    /// then a sequential tail (see module docs for the exact order).
    pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut lanes = [0.0f32; 8];
        let ca = a[..n].chunks_exact(8);
        let cb = b[..n].chunks_exact(8);
        let (ta, tb) = (ca.remainder(), cb.remainder());
        for (xs, ys) in ca.zip(cb) {
            for ((lane, &x), &y) in lanes.iter_mut().zip(xs).zip(ys) {
                *lane += x * y;
            }
        }
        let m = [
            lanes[0] + lanes[4],
            lanes[1] + lanes[5],
            lanes[2] + lanes[6],
            lanes[3] + lanes[7],
        ];
        let mut s = (m[0] + m[2]) + (m[1] + m[3]);
        for (&x, &y) in ta.iter().zip(tb) {
            s += x * y;
        }
        s
    }

    pub(super) fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        for (o, &v) in y.iter_mut().zip(x) {
            *o += alpha * v;
        }
    }

    pub(super) fn add_assign(acc: &mut [f32], src: &[f32]) {
        for (o, &v) in acc.iter_mut().zip(src) {
            *o += v;
        }
    }

    /// Canonical dense kron2: block count clamped to `a.len()`, final block
    /// truncated to `acc`, no zero skipping (see module docs).
    pub(super) fn kron2_accumulate(a: &[f32], b: &[f32], acc: &mut [f32]) {
        let q = b.len();
        if q == 0 {
            return;
        }
        let blocks = a.len().min(acc.len().div_ceil(q));
        for (i, &x) in a[..blocks].iter().enumerate() {
            let end = ((i + 1) * q).min(acc.len());
            axpy(x, b, &mut acc[i * q..end]);
        }
    }

    /// Code `i` of an LSB-first `bits`-wide packing (`bits ∈ {2, 4, 8}`,
    /// always a power of two, so codes never straddle word boundaries).
    #[inline]
    fn code_at(words: &[u32], i: usize, bits: usize) -> i32 {
        let per = 32 / bits;
        ((words[i / per] >> ((i % per) * bits)) & ((1u32 << bits) - 1)) as i32
    }

    /// Canonical sign-bit dot. Popcounts *whole* words, which is why
    /// padding bits past `q` must be zero (zero XOR zero contributes
    /// nothing).
    pub(super) fn idot_b1(a: &[u32], b: &[u32], q: usize) -> i32 {
        let words = q.div_ceil(32);
        let mut pop = 0u32;
        for (&x, &y) in a[..words].iter().zip(&b[..words]) {
            pop += (x ^ y).count_ones();
        }
        q as i32 - 2 * pop as i32
    }

    /// Canonical 2-bit dot over codes decoding to `2u - 3`.
    pub(super) fn idot_b2(a: &[u32], b: &[u32], q: usize) -> i32 {
        let mut s = 0i32;
        for i in 0..q {
            s += (2 * code_at(a, i, 2) - 3) * (2 * code_at(b, i, 2) - 3);
        }
        s
    }

    /// Canonical 4-bit dot over codes decoding to `u - 7`.
    pub(super) fn idot_i4(a: &[u32], b: &[u32], q: usize) -> i32 {
        let mut s = 0i32;
        for i in 0..q {
            s += (code_at(a, i, 4) - 7) * (code_at(b, i, 4) - 7);
        }
        s
    }

    /// Canonical 8-bit dot over codes decoding to `u - 127`.
    pub(super) fn idot_i8(a: &[u32], b: &[u32], q: usize) -> i32 {
        let mut s = 0i32;
        for i in 0..q {
            s += (code_at(a, i, 8) - 127) * (code_at(b, i, 8) - 127);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny deterministic generator; magnitudes vary across ~2^16 so sums
    /// actually round and association order is observable.
    struct Rng(u64);

    impl Rng {
        fn next_f32(&mut self) -> f32 {
            self.0 ^= self.0 << 13;
            self.0 ^= self.0 >> 7;
            self.0 ^= self.0 << 17;
            let unit = (self.0 >> 40) as f32 / (1u64 << 24) as f32 - 0.5;
            let scale = match self.0 & 3 {
                0 => 1.0e-3,
                1 => 1.0,
                2 => 64.0,
                _ => 4096.0,
            };
            unit * scale
        }

        fn vec(&mut self, n: usize) -> Vec<f32> {
            (0..n).map(|_| self.next_f32()).collect()
        }
    }

    /// The documented association order, written out naively. This is the
    /// golden: every level must reproduce these bits exactly.
    fn pinned_order_dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let chunks = n / 8;
        let mut lanes = [0.0f32; 8];
        for c in 0..chunks {
            for (l, lane) in lanes.iter_mut().enumerate() {
                *lane += a[c * 8 + l] * b[c * 8 + l];
            }
        }
        let m: Vec<f32> = (0..4).map(|j| lanes[j] + lanes[j + 4]).collect();
        let mut s = (m[0] + m[2]) + (m[1] + m[3]);
        for k in chunks * 8..n {
            s += a[k] * b[k];
        }
        s
    }

    fn test_lengths() -> Vec<usize> {
        // 0..64 catches every lane/tail combination at least eight times;
        // the large ones catch unaligned tails (remainders 1..=7) after
        // many full chunks.
        let mut lens: Vec<usize> = (0..=64).collect();
        lens.extend([1021, 1024, 1031, 2051, 4093, 8199]);
        lens
    }

    #[test]
    fn dot_matches_pinned_association_golden() {
        let mut rng = Rng(0x5eed_0001);
        for n in test_lengths() {
            let a = rng.vec(n);
            let b = rng.vec(n);
            let want = pinned_order_dot(&a, &b);
            for l in available_levels() {
                let got = dot_at(l, &a, &b);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "dot level={:?} n={} got={} want={}",
                    l,
                    n,
                    got,
                    want
                );
            }
            // The cached-level entry point must agree too.
            assert_eq!(dot(&a, &b).to_bits(), want.to_bits(), "dot() n={}", n);
        }
    }

    #[test]
    fn axpy_and_add_assign_parity_across_levels() {
        let mut rng = Rng(0x5eed_0002);
        for n in test_lengths() {
            let x = rng.vec(n);
            let base = rng.vec(n);
            let alpha = rng.next_f32();

            let mut want_axpy = base.clone();
            axpy_at(SimdLevel::Scalar, alpha, &x, &mut want_axpy);
            let mut want_add = base.clone();
            add_assign_at(SimdLevel::Scalar, &mut want_add, &x);

            for l in available_levels() {
                let mut got = base.clone();
                axpy_at(l, alpha, &x, &mut got);
                for (i, (g, w)) in got.iter().zip(&want_axpy).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "axpy level={:?} n={} i={}", l, n, i);
                }
                let mut got = base.clone();
                add_assign_at(l, &mut got, &x);
                for (i, (g, w)) in got.iter().zip(&want_add).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "add level={:?} n={} i={}", l, n, i);
                }
            }
        }
    }

    #[test]
    fn kron2_parity_across_levels_and_geometries() {
        let mut rng = Rng(0x5eed_0003);
        // (p, q, acc_len): exact fits, truncated finals, oversized accs
        // (the hardening clamp), the q == 4 fast path with even and odd
        // block counts, and degenerate shapes.
        let cases = [
            (0, 4, 8),
            (3, 0, 9),
            (1, 1, 1),
            (2, 3, 6),
            (2, 3, 5),
            (2, 3, 10),
            (7, 4, 28),
            (8, 4, 32),
            (8, 4, 30),
            (5, 4, 40),
            (3, 16, 48),
            (3, 16, 41),
            (4, 19, 76),
            (2, 257, 514),
        ];
        for &(p, q, acc_len) in &cases {
            let a = rng.vec(p);
            let b = rng.vec(q);
            let base = rng.vec(acc_len);

            let mut want = base.clone();
            kron2_accumulate_at(SimdLevel::Scalar, &a, &b, &mut want);
            for l in available_levels() {
                let mut got = base.clone();
                kron2_accumulate_at(l, &a, &b, &mut got);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "kron2 level={:?} p={} q={} acc={} i={}",
                        l,
                        p,
                        q,
                        acc_len,
                        i
                    );
                }
            }
        }
    }

    #[test]
    fn kron2_clamps_instead_of_panicking_on_short_factor() {
        // Regression: acc longer than a.len() * b.len() used to index `a`
        // out of bounds. The covered prefix accumulates; the rest is
        // untouched.
        let a = [2.0f32, 3.0];
        let b = [1.0f32, 10.0, 100.0];
        for l in available_levels() {
            let mut acc = vec![0.5f32; 10];
            kron2_accumulate_at(l, &a, &b, &mut acc);
            assert_eq!(
                &acc[..6],
                &[2.5, 20.5, 200.5, 3.5, 30.5, 300.5],
                "level={:?}",
                l
            );
            assert!(acc[6..].iter().all(|&v| v == 0.5), "level={:?}", l);
        }
    }

    #[test]
    fn kron2_is_dense_in_signed_zero_corners() {
        // 0.0 * b must still be *added* (a zero-skip would leave -0.0 in
        // place; adding +0.0 * 1.0 flips it to +0.0).
        for l in available_levels() {
            let mut acc = [-0.0f32; 2];
            kron2_accumulate_at(l, &[0.0], &[1.0, 1.0], &mut acc);
            assert_eq!(acc[0].to_bits(), 0.0f32.to_bits(), "level={:?}", l);
            assert_eq!(acc[1].to_bits(), 0.0f32.to_bits(), "level={:?}", l);
        }
    }

    /// LSB-first packing of one code stream, padding bits zero — the same
    /// layout `quant::encode_leaf` produces.
    fn pack(codes: &[u32], bits: usize) -> Vec<u32> {
        let per = 32 / bits;
        let mut words = vec![0u32; (codes.len() * bits).div_ceil(32)];
        for (i, &c) in codes.iter().enumerate() {
            words[i / per] |= c << ((i % per) * bits);
        }
        words
    }

    #[test]
    fn quant_idot_goldens_pin_scalar_semantics() {
        // b1: a = +1,-1,+1,+1,-1  b = +1,+1,+1,-1,-1 -> 1-1+1-1+1 = 1
        assert_eq!(idot_b1_at(SimdLevel::Scalar, &[0b01101], &[0b00111], 5), 1);
        // b2: a codes [0,3,2] -> {-3,+3,+1}; b codes [1,1,0] -> {-1,-1,-3}
        //     dot = 3 - 3 - 3 = -3
        let (a, b) = (pack(&[0, 3, 2], 2), pack(&[1, 1, 0], 2));
        assert_eq!(idot_b2_at(SimdLevel::Scalar, &a, &b, 3), -3);
        // i4: a codes [14,0,7] -> {+7,-7,0}; b codes [13,1,3] -> {+6,-6,-4}
        //     dot = 42 + 42 + 0 = 84
        let (a, b) = (pack(&[14, 0, 7], 4), pack(&[13, 1, 3], 4));
        assert_eq!(idot_i4_at(SimdLevel::Scalar, &a, &b, 3), 84);
        // i8: a codes [254,0] -> {+127,-127}; b codes [127,130] -> {0,+3}
        //     dot = 0 - 381 = -381
        let (a, b) = (pack(&[254, 0], 8), pack(&[127, 130], 8));
        assert_eq!(idot_i8_at(SimdLevel::Scalar, &a, &b, 2), -381);
        // Empty payloads are zero at every width.
        for l in available_levels() {
            assert_eq!(idot_b1_at(l, &[], &[], 0), 0, "level={l:?}");
            assert_eq!(idot_i8_at(l, &[], &[], 0), 0, "level={l:?}");
        }
    }

    #[test]
    fn quant_idot_parity_across_levels() {
        let mut rng = Rng(0x5eed_0010);
        let mut code = |bound: u32| {
            // Advance the xorshift state and draw a code below `bound`.
            let _ = rng.next_f32();
            ((rng.0 >> 24) as u32) % bound
        };
        let qs: Vec<usize> = {
            let mut v: Vec<usize> = (0..=40).collect();
            v.extend([63, 64, 65, 127, 128, 129, 255, 256, 1021, 4096]);
            v
        };
        for &q in &qs {
            // (bits, exclusive code bound): i8 stops at 255 — see idot_i8.
            for &(bits, bound) in &[(1usize, 2u32), (2, 4), (4, 16), (8, 255)] {
                let ca: Vec<u32> = (0..q).map(|_| code(bound)).collect();
                let cb: Vec<u32> = (0..q).map(|_| code(bound)).collect();
                let (a, b) = (pack(&ca, bits), pack(&cb, bits));
                let at = |l: SimdLevel| match bits {
                    1 => idot_b1_at(l, &a, &b, q),
                    2 => idot_b2_at(l, &a, &b, q),
                    4 => idot_i4_at(l, &a, &b, q),
                    _ => idot_i8_at(l, &a, &b, q),
                };
                let want = at(SimdLevel::Scalar);
                for l in available_levels() {
                    assert_eq!(at(l), want, "idot bits={bits} q={q} level={l:?}");
                }
                // The cached-level entry points must agree too.
                let got = match bits {
                    1 => idot_b1(&a, &b, q),
                    2 => idot_b2(&a, &b, q),
                    4 => idot_i4(&a, &b, q),
                    _ => idot_i8(&a, &b, q),
                };
                assert_eq!(got, want, "idot bits={bits} q={q} cached level");
            }
        }
    }

    #[test]
    fn parse_level_names() {
        assert_eq!(parse_level("scalar"), Some(SimdLevel::Scalar));
        assert_eq!(parse_level("SSE2"), Some(SimdLevel::Sse2));
        assert_eq!(parse_level("avx2"), Some(SimdLevel::Avx2Fma));
        assert_eq!(parse_level("avx2+fma"), Some(SimdLevel::Avx2Fma));
        assert_eq!(parse_level("auto"), None);
        assert_eq!(parse_level(""), None);
        assert_eq!(parse_level("neon"), None);
    }

    #[test]
    fn with_level_forces_and_clamps() {
        with_level(SimdLevel::Scalar, || {
            assert_eq!(level(), SimdLevel::Scalar);
        });
        // Requests above the CPU's ceiling clamp instead of lying.
        with_level(SimdLevel::Avx2Fma, || {
            assert!(level() <= detect());
        });
    }

    #[test]
    fn level_codes_and_names_are_stable() {
        assert_eq!(SimdLevel::Scalar.code(), 0);
        assert_eq!(SimdLevel::Sse2.code(), 1);
        assert_eq!(SimdLevel::Avx2Fma.code(), 2);
        assert_eq!(SimdLevel::Avx2Fma.name(), "avx2+fma");
        for l in available_levels() {
            assert_eq!(SimdLevel::from_code(l.code()), l);
        }
    }
}
