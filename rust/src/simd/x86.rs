//! x86_64 kernel implementations (SSE2 baseline + AVX2).
//!
//! Every function here reproduces the canonical bits of `super::scalar`
//! exactly — see the module docs in `simd/mod.rs` for the pinned
//! association order and the no-FMA rule. The `#[target_feature]`
//! functions are `unsafe fn`s whose single obligation is that the caller
//! has verified the feature is present; the dispatchers in `mod.rs` do so
//! by clamping every level to `detect()`.
//!
//! The AVX2 functions enable only `avx2` (which implies `avx`), not `fma`:
//! the parity-bound kernels must never be compiled in a context where a
//! mul/add pair could be contracted into a fused op.

use std::arch::x86_64::*;

/// Horizontal sum of a 4-lane register in the pinned reduction order:
/// `(m0 + m2) + (m1 + m3)`.
#[inline]
#[target_feature(enable = "sse2")]
unsafe fn hsum128(m: __m128) -> f32 {
    // movehl: (m2, m3, m2, m3); add: (m0+m2, m1+m3, ..).
    let folded = _mm_add_ps(m, _mm_movehl_ps(m, m));
    let lane1 = _mm_shuffle_ps::<1>(folded, folded);
    _mm_cvtss_f32(_mm_add_ss(folded, lane1))
}

#[target_feature(enable = "sse2")]
pub(super) unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let chunks = n / 8;
    // lo carries canonical lanes 0..4, hi lanes 4..8.
    let mut lo = _mm_setzero_ps();
    let mut hi = _mm_setzero_ps();
    for c in 0..chunks {
        let k = c * 8;
        lo = _mm_add_ps(
            lo,
            _mm_mul_ps(_mm_loadu_ps(ap.add(k)), _mm_loadu_ps(bp.add(k))),
        );
        hi = _mm_add_ps(
            hi,
            _mm_mul_ps(_mm_loadu_ps(ap.add(k + 4)), _mm_loadu_ps(bp.add(k + 4))),
        );
    }
    // lo + hi is exactly the m[j] = lane[j] + lane[j+4] fold.
    let mut s = hsum128(_mm_add_ps(lo, hi));
    for k in chunks * 8..n {
        s += *ap.add(k) * *bp.add(k);
    }
    s
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let k = c * 8;
        // mul + add, never fma: parity with the scalar lanes.
        acc = _mm256_add_ps(
            acc,
            _mm256_mul_ps(_mm256_loadu_ps(ap.add(k)), _mm256_loadu_ps(bp.add(k))),
        );
    }
    let m = _mm_add_ps(
        _mm256_castps256_ps128(acc),
        _mm256_extractf128_ps::<1>(acc),
    );
    let mut s = hsum128(m);
    for k in chunks * 8..n {
        s += *ap.add(k) * *bp.add(k);
    }
    s
}

#[target_feature(enable = "sse2")]
pub(super) unsafe fn axpy_sse2(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
    let va = _mm_set1_ps(alpha);
    let chunks = n / 4;
    for c in 0..chunks {
        let k = c * 4;
        let sum = _mm_add_ps(
            _mm_loadu_ps(yp.add(k)),
            _mm_mul_ps(va, _mm_loadu_ps(xp.add(k))),
        );
        _mm_storeu_ps(yp.add(k), sum);
    }
    for k in chunks * 4..n {
        *yp.add(k) += alpha * *xp.add(k);
    }
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
    let va = _mm256_set1_ps(alpha);
    let chunks = n / 8;
    for c in 0..chunks {
        let k = c * 8;
        let sum = _mm256_add_ps(
            _mm256_loadu_ps(yp.add(k)),
            _mm256_mul_ps(va, _mm256_loadu_ps(xp.add(k))),
        );
        _mm256_storeu_ps(yp.add(k), sum);
    }
    for k in chunks * 8..n {
        *yp.add(k) += alpha * *xp.add(k);
    }
}

#[target_feature(enable = "sse2")]
pub(super) unsafe fn add_assign_sse2(acc: &mut [f32], src: &[f32]) {
    let n = acc.len().min(src.len());
    let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
    let chunks = n / 4;
    for c in 0..chunks {
        let k = c * 4;
        let sum = _mm_add_ps(_mm_loadu_ps(ap.add(k)), _mm_loadu_ps(sp.add(k)));
        _mm_storeu_ps(ap.add(k), sum);
    }
    for k in chunks * 4..n {
        *ap.add(k) += *sp.add(k);
    }
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn add_assign_avx2(acc: &mut [f32], src: &[f32]) {
    let n = acc.len().min(src.len());
    let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
    let chunks = n / 8;
    for c in 0..chunks {
        let k = c * 8;
        let sum = _mm256_add_ps(_mm256_loadu_ps(ap.add(k)), _mm256_loadu_ps(sp.add(k)));
        _mm256_storeu_ps(ap.add(k), sum);
    }
    for k in chunks * 8..n {
        *ap.add(k) += *sp.add(k);
    }
}

/// Shared tail for the kron2 kernels: at most one block extends past the
/// end of `acc` (or `acc` stops mid-block); accumulate its covered prefix
/// sequentially. Elementwise, so bit-parity is automatic.
#[inline]
fn kron2_partial_tail(a: &[f32], b: &[f32], acc: &mut [f32], q: usize, full: usize) {
    let blocks = a.len().min(acc.len().div_ceil(q));
    if blocks > full {
        let x = a[full];
        for (o, &v) in acc[full * q..].iter_mut().zip(b) {
            *o += x * v;
        }
    }
}

#[target_feature(enable = "sse2")]
pub(super) unsafe fn kron2_sse2(a: &[f32], b: &[f32], acc: &mut [f32]) {
    let q = b.len();
    if q == 0 {
        return;
    }
    // Blocks that fit entirely inside both `a` and `acc` (hardening clamp).
    let full = a.len().min(acc.len() / q);
    if q == 4 {
        // Order-4 geometries put length-4 leaves in the final kron level:
        // one 128-bit op per block instead of a per-block axpy call.
        let (ap, bp, accp) = (a.as_ptr(), b.as_ptr(), acc.as_mut_ptr());
        let vb = _mm_loadu_ps(bp);
        for i in 0..full {
            let dst = accp.add(i * 4);
            let sum = _mm_add_ps(
                _mm_loadu_ps(dst),
                _mm_mul_ps(_mm_set1_ps(*ap.add(i)), vb),
            );
            _mm_storeu_ps(dst, sum);
        }
    } else {
        for i in 0..full {
            axpy_sse2(a[i], b, &mut acc[i * q..(i + 1) * q]);
        }
    }
    kron2_partial_tail(a, b, acc, q, full);
}

// ---------------------------------------------------------------------------
// Quantized-domain integer dot kernels. Accumulation is exact i32
// arithmetic, so parity with the scalar definitions holds for *any* lane
// layout — these pick whatever unpack is fastest. SSE2 lacks the byte
// shuffle the b1 popcount and the nibble/crumb unpacks want (SSSE3+), so
// only i8 gets a genuine SSE2 path; the dispatcher falls back to scalar
// for the others.
// ---------------------------------------------------------------------------

/// Sum the 8 i32 lanes of an accumulator.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(acc: __m256i) -> i32 {
    let mut parts = [0i32; 8];
    _mm256_storeu_si256(parts.as_mut_ptr() as *mut __m256i, acc);
    parts.iter().sum()
}

/// Widen two centered-code byte vectors (values within i8) to i16 halves
/// and multiply-accumulate their products into `acc`'s i32 lanes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn mac_epi8(acc: __m256i, ca: __m256i, cb: __m256i) -> __m256i {
    let a_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(ca));
    let a_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(ca));
    let b_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(cb));
    let b_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(cb));
    let acc = _mm256_add_epi32(acc, _mm256_madd_epi16(a_lo, b_lo));
    _mm256_add_epi32(acc, _mm256_madd_epi16(a_hi, b_hi))
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn idot_b1_avx2(a: &[u32], b: &[u32], q: usize) -> i32 {
    let words = q.div_ceil(32);
    let vec_words = words / 8 * 8;
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut pop = 0i64;
    if vec_words > 0 {
        // Per-nibble popcount LUT (Mula's method): shuffle each nibble
        // through a 0..15 -> bit-count table, add low+high counts, then
        // SAD against zero to widen the byte counts into u64 lanes.
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let mut acc = _mm256_setzero_si256();
        let mut w = 0;
        while w < vec_words {
            let x = _mm256_xor_si256(
                _mm256_loadu_si256(ap.add(w) as *const __m256i),
                _mm256_loadu_si256(bp.add(w) as *const __m256i),
            );
            let lo = _mm256_and_si256(x, low);
            let hi = _mm256_and_si256(_mm256_srli_epi32::<4>(x), low);
            let cnt =
                _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
            w += 8;
        }
        let mut parts = [0i64; 4];
        _mm256_storeu_si256(parts.as_mut_ptr() as *mut __m256i, acc);
        pop = parts.iter().sum();
    }
    for w in vec_words..words {
        pop += i64::from((*ap.add(w) ^ *bp.add(w)).count_ones());
    }
    q as i32 - 2 * pop as i32
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn idot_b2_avx2(a: &[u32], b: &[u32], q: usize) -> i32 {
    let vec_words = (q / 16) / 8 * 8; // 128 codes per 256-bit chunk
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mask = _mm256_set1_epi8(0x03);
    let bias = _mm256_set1_epi8(3);
    let mut acc = _mm256_setzero_si256();
    let mut w = 0;
    while w < vec_words {
        let xa = _mm256_loadu_si256(ap.add(w) as *const __m256i);
        let xb = _mm256_loadu_si256(bp.add(w) as *const __m256i);
        // Crumb r of byte k is code 4k + r — the same position in both
        // operands, so each of the four shift rounds pairs up correctly.
        // c = 2u - 3 via u+u then -3, all within i8.
        let ua0 = _mm256_and_si256(xa, mask);
        let ua1 = _mm256_and_si256(_mm256_srli_epi16::<2>(xa), mask);
        let ua2 = _mm256_and_si256(_mm256_srli_epi16::<4>(xa), mask);
        let ua3 = _mm256_and_si256(_mm256_srli_epi16::<6>(xa), mask);
        let ub0 = _mm256_and_si256(xb, mask);
        let ub1 = _mm256_and_si256(_mm256_srli_epi16::<2>(xb), mask);
        let ub2 = _mm256_and_si256(_mm256_srli_epi16::<4>(xb), mask);
        let ub3 = _mm256_and_si256(_mm256_srli_epi16::<6>(xb), mask);
        acc = mac_epi8(
            acc,
            _mm256_sub_epi8(_mm256_add_epi8(ua0, ua0), bias),
            _mm256_sub_epi8(_mm256_add_epi8(ub0, ub0), bias),
        );
        acc = mac_epi8(
            acc,
            _mm256_sub_epi8(_mm256_add_epi8(ua1, ua1), bias),
            _mm256_sub_epi8(_mm256_add_epi8(ub1, ub1), bias),
        );
        acc = mac_epi8(
            acc,
            _mm256_sub_epi8(_mm256_add_epi8(ua2, ua2), bias),
            _mm256_sub_epi8(_mm256_add_epi8(ub2, ub2), bias),
        );
        acc = mac_epi8(
            acc,
            _mm256_sub_epi8(_mm256_add_epi8(ua3, ua3), bias),
            _mm256_sub_epi8(_mm256_add_epi8(ub3, ub3), bias),
        );
        w += 8;
    }
    let mut s = hsum_epi32(acc);
    for i in vec_words * 16..q {
        let ua = ((*ap.add(i / 16) >> ((i % 16) * 2)) & 0x03) as i32;
        let ub = ((*bp.add(i / 16) >> ((i % 16) * 2)) & 0x03) as i32;
        s += (2 * ua - 3) * (2 * ub - 3);
    }
    s
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn idot_i4_avx2(a: &[u32], b: &[u32], q: usize) -> i32 {
    let vec_words = (q / 8) / 8 * 8; // 64 codes per 256-bit chunk
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mask = _mm256_set1_epi8(0x0f);
    let bias = _mm256_set1_epi8(7);
    let mut acc = _mm256_setzero_si256();
    let mut w = 0;
    while w < vec_words {
        let xa = _mm256_loadu_si256(ap.add(w) as *const __m256i);
        let xb = _mm256_loadu_si256(bp.add(w) as *const __m256i);
        // Low nibbles are the even code positions, high nibbles the odd
        // ones — matching positions in `a` and `b`, so products pair up.
        let ca0 = _mm256_sub_epi8(_mm256_and_si256(xa, mask), bias);
        let cb0 = _mm256_sub_epi8(_mm256_and_si256(xb, mask), bias);
        let ca1 = _mm256_sub_epi8(_mm256_and_si256(_mm256_srli_epi16::<4>(xa), mask), bias);
        let cb1 = _mm256_sub_epi8(_mm256_and_si256(_mm256_srli_epi16::<4>(xb), mask), bias);
        acc = mac_epi8(acc, ca0, cb0);
        acc = mac_epi8(acc, ca1, cb1);
        w += 8;
    }
    let mut s = hsum_epi32(acc);
    for i in vec_words * 8..q {
        let ua = ((*ap.add(i / 8) >> ((i % 8) * 4)) & 0x0f) as i32;
        let ub = ((*bp.add(i / 8) >> ((i % 8) * 4)) & 0x0f) as i32;
        s += (ua - 7) * (ub - 7);
    }
    s
}

#[target_feature(enable = "sse2")]
pub(super) unsafe fn idot_i8_sse2(a: &[u32], b: &[u32], q: usize) -> i32 {
    let vec_words = (q / 4) / 4 * 4; // 16 codes per 128-bit chunk
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let bias = _mm_set1_epi8(127);
    let mut acc = _mm_setzero_si128();
    let mut w = 0;
    while w < vec_words {
        let ca = _mm_sub_epi8(_mm_loadu_si128(ap.add(w) as *const __m128i), bias);
        let cb = _mm_sub_epi8(_mm_loadu_si128(bp.add(w) as *const __m128i), bias);
        // Sign-extend bytes to i16 by duplicating each byte into the high
        // half and arithmetic-shifting back down (the pre-SSE4.1 idiom).
        let a_lo = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(ca, ca));
        let a_hi = _mm_srai_epi16::<8>(_mm_unpackhi_epi8(ca, ca));
        let b_lo = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(cb, cb));
        let b_hi = _mm_srai_epi16::<8>(_mm_unpackhi_epi8(cb, cb));
        acc = _mm_add_epi32(acc, _mm_madd_epi16(a_lo, b_lo));
        acc = _mm_add_epi32(acc, _mm_madd_epi16(a_hi, b_hi));
        w += 4;
    }
    let mut parts = [0i32; 4];
    _mm_storeu_si128(parts.as_mut_ptr() as *mut __m128i, acc);
    let mut s: i32 = parts.iter().sum();
    for i in vec_words * 4..q {
        let ua = ((*ap.add(i / 4) >> ((i % 4) * 8)) & 0xff) as i32;
        let ub = ((*bp.add(i / 4) >> ((i % 4) * 8)) & 0xff) as i32;
        s += (ua - 127) * (ub - 127);
    }
    s
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn idot_i8_avx2(a: &[u32], b: &[u32], q: usize) -> i32 {
    let vec_words = (q / 4) / 8 * 8; // 32 codes per 256-bit chunk
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let bias = _mm256_set1_epi8(127);
    let mut acc = _mm256_setzero_si256();
    let mut w = 0;
    while w < vec_words {
        let ca = _mm256_sub_epi8(_mm256_loadu_si256(ap.add(w) as *const __m256i), bias);
        let cb = _mm256_sub_epi8(_mm256_loadu_si256(bp.add(w) as *const __m256i), bias);
        acc = mac_epi8(acc, ca, cb);
        w += 8;
    }
    let mut s = hsum_epi32(acc);
    for i in vec_words * 4..q {
        let ua = ((*ap.add(i / 4) >> ((i % 4) * 8)) & 0xff) as i32;
        let ub = ((*bp.add(i / 4) >> ((i % 4) * 8)) & 0xff) as i32;
        s += (ua - 127) * (ub - 127);
    }
    s
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn kron2_avx2(a: &[f32], b: &[f32], acc: &mut [f32]) {
    let q = b.len();
    if q == 0 {
        return;
    }
    let full = a.len().min(acc.len() / q);
    if q == 4 {
        // Pack two length-4 blocks per 256-bit op: lane layout is
        // (a[i]·b | a[i+1]·b), matching `acc[i*4..i*4+8]` exactly.
        let (ap, bp, accp) = (a.as_ptr(), b.as_ptr(), acc.as_mut_ptr());
        let vb = _mm_loadu_ps(bp);
        let vbb = _mm256_set_m128(vb, vb);
        let pairs = full / 2;
        for p in 0..pairs {
            let i = p * 2;
            let va = _mm256_set_m128(_mm_set1_ps(*ap.add(i + 1)), _mm_set1_ps(*ap.add(i)));
            let dst = accp.add(i * 4);
            let sum = _mm256_add_ps(_mm256_loadu_ps(dst), _mm256_mul_ps(va, vbb));
            _mm256_storeu_ps(dst, sum);
        }
        if full % 2 == 1 {
            let i = full - 1;
            let dst = accp.add(i * 4);
            let sum = _mm_add_ps(
                _mm_loadu_ps(dst),
                _mm_mul_ps(_mm_set1_ps(*ap.add(i)), vb),
            );
            _mm_storeu_ps(dst, sum);
        }
    } else {
        for i in 0..full {
            axpy_avx2(a[i], b, &mut acc[i * q..(i + 1) * q]);
        }
    }
    kron2_partial_tail(a, b, acc, q, full);
}
