//! x86_64 kernel implementations (SSE2 baseline + AVX2).
//!
//! Every function here reproduces the canonical bits of `super::scalar`
//! exactly — see the module docs in `simd/mod.rs` for the pinned
//! association order and the no-FMA rule. The `#[target_feature]`
//! functions are `unsafe fn`s whose single obligation is that the caller
//! has verified the feature is present; the dispatchers in `mod.rs` do so
//! by clamping every level to `detect()`.
//!
//! The AVX2 functions enable only `avx2` (which implies `avx`), not `fma`:
//! the parity-bound kernels must never be compiled in a context where a
//! mul/add pair could be contracted into a fused op.

use std::arch::x86_64::*;

/// Horizontal sum of a 4-lane register in the pinned reduction order:
/// `(m0 + m2) + (m1 + m3)`.
#[inline]
#[target_feature(enable = "sse2")]
unsafe fn hsum128(m: __m128) -> f32 {
    // movehl: (m2, m3, m2, m3); add: (m0+m2, m1+m3, ..).
    let folded = _mm_add_ps(m, _mm_movehl_ps(m, m));
    let lane1 = _mm_shuffle_ps::<1>(folded, folded);
    _mm_cvtss_f32(_mm_add_ss(folded, lane1))
}

#[target_feature(enable = "sse2")]
pub(super) unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let chunks = n / 8;
    // lo carries canonical lanes 0..4, hi lanes 4..8.
    let mut lo = _mm_setzero_ps();
    let mut hi = _mm_setzero_ps();
    for c in 0..chunks {
        let k = c * 8;
        lo = _mm_add_ps(
            lo,
            _mm_mul_ps(_mm_loadu_ps(ap.add(k)), _mm_loadu_ps(bp.add(k))),
        );
        hi = _mm_add_ps(
            hi,
            _mm_mul_ps(_mm_loadu_ps(ap.add(k + 4)), _mm_loadu_ps(bp.add(k + 4))),
        );
    }
    // lo + hi is exactly the m[j] = lane[j] + lane[j+4] fold.
    let mut s = hsum128(_mm_add_ps(lo, hi));
    for k in chunks * 8..n {
        s += *ap.add(k) * *bp.add(k);
    }
    s
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let chunks = n / 8;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let k = c * 8;
        // mul + add, never fma: parity with the scalar lanes.
        acc = _mm256_add_ps(
            acc,
            _mm256_mul_ps(_mm256_loadu_ps(ap.add(k)), _mm256_loadu_ps(bp.add(k))),
        );
    }
    let m = _mm_add_ps(
        _mm256_castps256_ps128(acc),
        _mm256_extractf128_ps::<1>(acc),
    );
    let mut s = hsum128(m);
    for k in chunks * 8..n {
        s += *ap.add(k) * *bp.add(k);
    }
    s
}

#[target_feature(enable = "sse2")]
pub(super) unsafe fn axpy_sse2(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
    let va = _mm_set1_ps(alpha);
    let chunks = n / 4;
    for c in 0..chunks {
        let k = c * 4;
        let sum = _mm_add_ps(
            _mm_loadu_ps(yp.add(k)),
            _mm_mul_ps(va, _mm_loadu_ps(xp.add(k))),
        );
        _mm_storeu_ps(yp.add(k), sum);
    }
    for k in chunks * 4..n {
        *yp.add(k) += alpha * *xp.add(k);
    }
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
    let va = _mm256_set1_ps(alpha);
    let chunks = n / 8;
    for c in 0..chunks {
        let k = c * 8;
        let sum = _mm256_add_ps(
            _mm256_loadu_ps(yp.add(k)),
            _mm256_mul_ps(va, _mm256_loadu_ps(xp.add(k))),
        );
        _mm256_storeu_ps(yp.add(k), sum);
    }
    for k in chunks * 8..n {
        *yp.add(k) += alpha * *xp.add(k);
    }
}

#[target_feature(enable = "sse2")]
pub(super) unsafe fn add_assign_sse2(acc: &mut [f32], src: &[f32]) {
    let n = acc.len().min(src.len());
    let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
    let chunks = n / 4;
    for c in 0..chunks {
        let k = c * 4;
        let sum = _mm_add_ps(_mm_loadu_ps(ap.add(k)), _mm_loadu_ps(sp.add(k)));
        _mm_storeu_ps(ap.add(k), sum);
    }
    for k in chunks * 4..n {
        *ap.add(k) += *sp.add(k);
    }
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn add_assign_avx2(acc: &mut [f32], src: &[f32]) {
    let n = acc.len().min(src.len());
    let (ap, sp) = (acc.as_mut_ptr(), src.as_ptr());
    let chunks = n / 8;
    for c in 0..chunks {
        let k = c * 8;
        let sum = _mm256_add_ps(_mm256_loadu_ps(ap.add(k)), _mm256_loadu_ps(sp.add(k)));
        _mm256_storeu_ps(ap.add(k), sum);
    }
    for k in chunks * 8..n {
        *ap.add(k) += *sp.add(k);
    }
}

/// Shared tail for the kron2 kernels: at most one block extends past the
/// end of `acc` (or `acc` stops mid-block); accumulate its covered prefix
/// sequentially. Elementwise, so bit-parity is automatic.
#[inline]
fn kron2_partial_tail(a: &[f32], b: &[f32], acc: &mut [f32], q: usize, full: usize) {
    let blocks = a.len().min(acc.len().div_ceil(q));
    if blocks > full {
        let x = a[full];
        for (o, &v) in acc[full * q..].iter_mut().zip(b) {
            *o += x * v;
        }
    }
}

#[target_feature(enable = "sse2")]
pub(super) unsafe fn kron2_sse2(a: &[f32], b: &[f32], acc: &mut [f32]) {
    let q = b.len();
    if q == 0 {
        return;
    }
    // Blocks that fit entirely inside both `a` and `acc` (hardening clamp).
    let full = a.len().min(acc.len() / q);
    if q == 4 {
        // Order-4 geometries put length-4 leaves in the final kron level:
        // one 128-bit op per block instead of a per-block axpy call.
        let (ap, bp, accp) = (a.as_ptr(), b.as_ptr(), acc.as_mut_ptr());
        let vb = _mm_loadu_ps(bp);
        for i in 0..full {
            let dst = accp.add(i * 4);
            let sum = _mm_add_ps(
                _mm_loadu_ps(dst),
                _mm_mul_ps(_mm_set1_ps(*ap.add(i)), vb),
            );
            _mm_storeu_ps(dst, sum);
        }
    } else {
        for i in 0..full {
            axpy_sse2(a[i], b, &mut acc[i * q..(i + 1) * q]);
        }
    }
    kron2_partial_tail(a, b, acc, q, full);
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn kron2_avx2(a: &[f32], b: &[f32], acc: &mut [f32]) {
    let q = b.len();
    if q == 0 {
        return;
    }
    let full = a.len().min(acc.len() / q);
    if q == 4 {
        // Pack two length-4 blocks per 256-bit op: lane layout is
        // (a[i]·b | a[i+1]·b), matching `acc[i*4..i*4+8]` exactly.
        let (ap, bp, accp) = (a.as_ptr(), b.as_ptr(), acc.as_mut_ptr());
        let vb = _mm_loadu_ps(bp);
        let vbb = _mm256_set_m128(vb, vb);
        let pairs = full / 2;
        for p in 0..pairs {
            let i = p * 2;
            let va = _mm256_set_m128(_mm_set1_ps(*ap.add(i + 1)), _mm_set1_ps(*ap.add(i)));
            let dst = accp.add(i * 4);
            let sum = _mm256_add_ps(_mm256_loadu_ps(dst), _mm256_mul_ps(va, vbb));
            _mm256_storeu_ps(dst, sum);
        }
        if full % 2 == 1 {
            let i = full - 1;
            let dst = accp.add(i * 4);
            let sum = _mm_add_ps(
                _mm_loadu_ps(dst),
                _mm_mul_ps(_mm_set1_ps(*ap.add(i)), vb),
            );
            _mm_storeu_ps(dst, sum);
        }
    } else {
        for i in 0..full {
            axpy_avx2(a[i], b, &mut acc[i * q..(i + 1) * q]);
        }
    }
    kron2_partial_tail(a, b, acc, q, full);
}
