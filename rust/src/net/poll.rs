//! Readiness polling behind one small API: epoll on Linux, `poll(2)` on
//! other unix platforms. Level-triggered semantics on both — the reactor
//! reads until `WouldBlock`, so a level-triggered wakeup it does not fully
//! drain simply re-fires, which is impossible to get wrong in the way
//! edge-triggered wakeups are.

use super::sys;
use std::io;

#[cfg(unix)]
use std::os::unix::io::RawFd;

/// One readiness report. `token` is the caller's identifier from
/// [`Poller::register`] (the reactor uses connection-slab slots).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
    /// Peer hung up or the socket errored; the read path will observe the
    /// EOF/error, the flag only guarantees the wakeup is not silently empty.
    pub hangup: bool,
}

/// How many kernel events one `wait` call can surface.
const WAIT_BATCH: usize = 1024;

#[cfg(target_os = "linux")]
pub struct Poller {
    epfd: RawFd,
    buf: Vec<sys::raw::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl Poller {
    pub fn new() -> io::Result<Poller> {
        let epfd = unsafe { sys::raw::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller {
            epfd,
            buf: vec![sys::raw::EpollEvent { events: 0, data: 0 }; WAIT_BATCH],
        })
    }

    fn ctl(&mut self, op: i32, fd: RawFd, token: usize, read: bool, write: bool) -> io::Result<()> {
        let mut events = 0u32;
        if read {
            events |= sys::EPOLLIN;
        }
        if write {
            events |= sys::EPOLLOUT;
        }
        let mut ev = sys::raw::EpollEvent { events, data: token as u64 };
        let rc = unsafe { sys::raw::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    pub fn register(&mut self, fd: RawFd, token: usize, read: bool, write: bool) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, read, write)
    }

    /// Change the interest set of an already-registered fd.
    pub fn rearm(&mut self, fd: RawFd, token: usize, read: bool, write: bool) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, read, write)
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, false, false)
    }

    /// Block up to `timeout_ms` (-1 = forever) and append readiness reports
    /// to `out`. A signal interruption returns cleanly with no events.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        let n = unsafe {
            sys::raw::epoll_wait(
                self.epfd,
                self.buf.as_mut_ptr(),
                self.buf.len() as i32,
                timeout_ms,
            )
        };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for &ev in self.buf.iter().take(n as usize) {
            // `ev` is a copy out of the (possibly packed) struct.
            let mask = ev.events;
            let hangup = mask & (sys::EPOLLHUP | sys::EPOLLERR) != 0;
            out.push(Event {
                token: ev.data as usize,
                readable: mask & sys::EPOLLIN != 0 || hangup,
                writable: mask & sys::EPOLLOUT != 0,
                hangup,
            });
        }
        Ok(())
    }
}

#[cfg(target_os = "linux")]
impl Drop for Poller {
    fn drop(&mut self) {
        unsafe { sys::raw::close(self.epfd) };
    }
}

/// `poll(2)` fallback for non-Linux unix: the registration table lives in
/// userspace and the pollfd array is rebuilt per wait. O(n) per call — fine
/// for the fallback role; Linux (CI, production) takes the epoll path.
#[cfg(all(unix, not(target_os = "linux")))]
pub struct Poller {
    reg: Vec<(RawFd, usize, bool, bool)>,
}

#[cfg(all(unix, not(target_os = "linux")))]
impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { reg: Vec::new() })
    }

    pub fn register(&mut self, fd: RawFd, token: usize, read: bool, write: bool) -> io::Result<()> {
        self.reg.push((fd, token, read, write));
        Ok(())
    }

    pub fn rearm(&mut self, fd: RawFd, token: usize, read: bool, write: bool) -> io::Result<()> {
        match self.reg.iter_mut().find(|r| r.0 == fd) {
            Some(r) => {
                *r = (fd, token, read, write);
                Ok(())
            }
            None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
        }
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.reg.retain(|r| r.0 != fd);
        Ok(())
    }

    pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
        let mut fds: Vec<sys::raw::PollFd> = self
            .reg
            .iter()
            .map(|&(fd, _, read, write)| {
                let mut events = 0i16;
                if read {
                    events |= sys::POLLIN;
                }
                if write {
                    events |= sys::POLLOUT;
                }
                sys::raw::PollFd { fd, events, revents: 0 }
            })
            .collect();
        let n = unsafe { sys::raw::poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for (pfd, &(_, token, _, _)) in fds.iter().zip(&self.reg) {
            if pfd.revents == 0 {
                continue;
            }
            let hangup = pfd.revents & (sys::POLLHUP | sys::POLLERR) != 0;
            out.push(Event {
                token,
                readable: pfd.revents & sys::POLLIN != 0 || hangup,
                writable: pfd.revents & sys::POLLOUT != 0,
                hangup,
            });
        }
        Ok(())
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn readable_fires_only_after_data_arrives() {
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.register(b.as_raw_fd(), 7, true, false).unwrap();

        let mut events = Vec::new();
        p.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "no data yet: {events:?}");

        a.write_all(b"x").unwrap();
        p.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        p.deregister(b.as_raw_fd()).unwrap();
    }

    #[test]
    fn writable_interest_rearms() {
        let (a, _b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.register(a.as_raw_fd(), 3, true, false).unwrap();
        // A fresh socket with write interest reports writable immediately.
        p.rearm(a.as_raw_fd(), 3, true, true).unwrap();
        let mut events = Vec::new();
        p.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 3 && e.writable), "{events:?}");
        // Dropping write interest silences it again.
        p.rearm(a.as_raw_fd(), 3, true, false).unwrap();
        events.clear();
        p.wait(&mut events, 0).unwrap();
        assert!(events.iter().all(|e| !e.writable), "{events:?}");
    }

    #[test]
    fn hangup_is_surfaced_as_readable() {
        let (a, b) = UnixStream::pair().unwrap();
        let mut p = Poller::new().unwrap();
        p.register(b.as_raw_fd(), 9, true, false).unwrap();
        drop(a);
        let mut events = Vec::new();
        p.wait(&mut events, 1000).unwrap();
        assert!(
            events.iter().any(|e| e.token == 9 && e.readable),
            "peer close must wake the read path: {events:?}"
        );
    }
}
