//! Incremental request parsing for the event-loop driver.
//!
//! The blocking driver can lean on `Read::read_exact` / `read_line`; the
//! reactor only ever has *whatever bytes have arrived so far*. These
//! functions implement the same protocol grammar as the blocking readers in
//! [`crate::serving::wire`] over a byte buffer, returning "incomplete"
//! instead of blocking. Every decision that the blocking path takes (caps,
//! hostile-header handling, UTF-8 failures, the line-length ceiling) is
//! mirrored here so the two drivers answer byte-identically; the shared
//! request type ([`wire::BinRequest`]) and response builder live in `wire`
//! itself, so a frame parsed here and a frame read blockingly dispatch into
//! the exact same code.

use crate::obs::TraceContext;
use crate::serving::wire::{self, BinRequest};

/// First-byte protocol sniff over buffered bytes (mirrors the blocking
/// listener's `fill_buf` + magic verification).
#[derive(Debug, PartialEq, Eq)]
pub enum Sniff {
    /// Not enough bytes buffered to decide.
    Incomplete,
    /// Line-oriented text protocol; no bytes consumed.
    Text,
    /// Binary magic verified; 4 bytes consumed, server hello is owed.
    Binary,
    /// First byte was `MAGIC[0]` but the preamble mismatched: reply
    /// `ERR bad magic\n` and close (same as the blocking driver).
    BadMagic,
}

pub fn sniff(buf: &[u8]) -> Sniff {
    if buf.is_empty() {
        return Sniff::Incomplete;
    }
    if buf[0] != wire::MAGIC[0] {
        return Sniff::Text;
    }
    if buf.len() < wire::MAGIC.len() {
        return Sniff::Incomplete;
    }
    if buf[..wire::MAGIC.len()] == wire::MAGIC {
        Sniff::Binary
    } else {
        Sniff::BadMagic
    }
}

/// One step of text-line extraction.
#[derive(Debug, PartialEq, Eq)]
pub enum LineStep {
    /// No complete line buffered yet.
    Incomplete,
    /// `max` bytes buffered with no newline: the stream is unparseable from
    /// here (reply `ERR line too long\n`, close) — the blocking driver's
    /// `take(MAX_LINE_BYTES)` cap, incrementally.
    TooLong,
    /// One complete line. `consumed` includes the newline; `text` is `None`
    /// when the bytes are not UTF-8 (the blocking `read_line` fails the
    /// same way: the connection closes without a reply).
    Line { consumed: usize, text: Option<String> },
}

/// Extract the next newline-terminated line from `buf`, capped at `max`
/// bytes (newline included).
pub fn next_line(buf: &[u8], max: usize) -> LineStep {
    match buf.iter().take(max).position(|&b| b == b'\n') {
        Some(i) => LineStep::Line {
            consumed: i + 1,
            text: String::from_utf8(buf[..=i].to_vec()).ok(),
        },
        None if buf.len() >= max => LineStep::TooLong,
        None => LineStep::Incomplete,
    }
}

/// A partial line cut off by EOF: the blocking `read_line` still returns
/// (and the dispatcher still processes) the unterminated tail, so the
/// reactor does the same when the peer half-closes mid-line.
pub fn eof_line(buf: &[u8]) -> LineStep {
    LineStep::Line { consumed: buf.len(), text: String::from_utf8(buf.to_vec()).ok() }
}

/// Try to parse one complete binary request frame from the front of `buf`.
///
/// Returns `None` while the frame is still incomplete, otherwise the byte
/// count consumed plus the request. Hostile count headers return
/// [`BinRequest::Fatal`] after only the 8 header bytes — exactly like the
/// blocking reader, the claimed payload (and any trace-context extension)
/// is never waited for or allocated. A header with [`wire::OP_TRACE_CTX`]
/// set needs 24 extension bytes between header and payload; the decoded
/// request comes back wrapped in [`BinRequest::Traced`] with `parse_us` 0
/// (the reactor stamps the measured parse time before dispatch).
pub fn next_frame(buf: &[u8]) -> Option<(usize, BinRequest)> {
    if buf.len() < 8 {
        return None;
    }
    let word = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
    let count = u32::from_le_bytes([buf[4], buf[5], buf[6], buf[7]]);
    let op = word & !wire::OP_TRACE_CTX;
    let traced = word & wire::OP_TRACE_CTX != 0;
    if wire::count_is_hostile(op, count) {
        return Some((8, BinRequest::Fatal));
    }
    // Payload begins after the optional 24-byte trace-context extension;
    // every `need` below includes it, so a partial extension is just an
    // incomplete frame.
    let hdr = if traced { 8 + 24 } else { 8 };
    let (consumed, inner) = if op == wire::OP_RELOAD {
        let need = hdr + count as usize;
        if buf.len() < need {
            return None;
        }
        let path = String::from_utf8(buf[hdr..need].to_vec()).ok();
        (need, BinRequest::Reload { path })
    } else if op == wire::OP_KNN_VEC {
        let need = hdr + 4 + count as usize * 4;
        if buf.len() < need {
            return None;
        }
        let k = u32::from_le_bytes([buf[hdr], buf[hdr + 1], buf[hdr + 2], buf[hdr + 3]]);
        let query = buf[hdr + 4..need]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        (need, BinRequest::KnnVec { k, query })
    } else {
        let need = hdr + count as usize * 4;
        if buf.len() < need {
            return None;
        }
        let ids = buf[hdr..need]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        (need, BinRequest::Ids { op, ids })
    };
    if traced {
        let trace_id = u128::from_le_bytes(buf[8..24].try_into().expect("16 ctx bytes"));
        let span_id = u64::from_le_bytes(buf[24..32].try_into().expect("8 ctx bytes"));
        let ctx = TraceContext { trace_id, span_id };
        Some((consumed, BinRequest::Traced { ctx, parse_us: 0, inner: Box::new(inner) }))
    } else {
        Some((consumed, inner))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(op: u32, payload: &[u8], count: u32) -> Vec<u8> {
        let mut f = Vec::new();
        f.extend_from_slice(&op.to_le_bytes());
        f.extend_from_slice(&count.to_le_bytes());
        f.extend_from_slice(payload);
        f
    }

    #[test]
    fn sniff_distinguishes_text_binary_and_garbage() {
        assert_eq!(sniff(b""), Sniff::Incomplete);
        assert_eq!(sniff(b"L"), Sniff::Text);
        assert_eq!(sniff(b"LOOKUP 1\n"), Sniff::Text);
        assert_eq!(sniff(&wire::MAGIC[..1]), Sniff::Incomplete);
        assert_eq!(sniff(&wire::MAGIC[..3]), Sniff::Incomplete);
        assert_eq!(sniff(&wire::MAGIC), Sniff::Binary);
        let mut bad = wire::MAGIC;
        bad[2] ^= 0xFF;
        assert_eq!(sniff(&bad), Sniff::BadMagic);
    }

    #[test]
    fn lines_extract_incrementally() {
        assert_eq!(next_line(b"STATS", 64), LineStep::Incomplete);
        match next_line(b"STATS\nPING\n", 64) {
            LineStep::Line { consumed, text } => {
                assert_eq!(consumed, 6);
                assert_eq!(text.as_deref(), Some("STATS\n"));
            }
            other => panic!("{other:?}"),
        }
        // Cap semantics: a newline at exactly the cap still parses; one past
        // the cap is rejected, mirroring the blocking take(MAX) reader.
        let mut at_cap = vec![b'x'; 7];
        at_cap.push(b'\n');
        assert!(matches!(next_line(&at_cap, 8), LineStep::Line { consumed: 8, .. }));
        let mut past = vec![b'x'; 8];
        past.push(b'\n');
        assert_eq!(next_line(&past, 8), LineStep::TooLong);
        assert_eq!(next_line(&[b'x'; 8], 8), LineStep::TooLong);
        // Invalid UTF-8 in a complete line closes silently (text = None).
        assert!(matches!(
            next_line(&[0xC3, 0x28, b'\n'], 64),
            LineStep::Line { consumed: 3, text: None }
        ));
    }

    #[test]
    fn frames_parse_only_when_complete() {
        let mut f = frame(wire::OP_LOOKUP, &[], 2);
        f.extend_from_slice(&7u32.to_le_bytes());
        f.extend_from_slice(&9u32.to_le_bytes());
        // Dribble: every strict prefix is incomplete, the full frame parses.
        for cut in 0..f.len() {
            assert!(next_frame(&f[..cut]).is_none(), "cut={cut}");
        }
        match next_frame(&f) {
            Some((consumed, BinRequest::Ids { op, ids })) => {
                assert_eq!(consumed, f.len());
                assert_eq!(op, wire::OP_LOOKUP);
                assert_eq!(ids, vec![7, 9]);
            }
            other => panic!("{other:?}"),
        }
        // Pipelined: a second frame behind the first is untouched.
        let mut two = f.clone();
        two.extend_from_slice(&frame(wire::OP_STATS, &[], 0));
        let (consumed, _) = next_frame(&two).unwrap();
        assert_eq!(consumed, f.len());
        assert!(matches!(
            next_frame(&two[consumed..]),
            Some((8, BinRequest::Ids { op: wire::OP_STATS, .. }))
        ));
    }

    #[test]
    fn hostile_headers_are_fatal_without_waiting_for_payload() {
        // 4 GiB id count: fatal after just the header, nothing allocated.
        assert!(matches!(
            next_frame(&frame(wire::OP_LOOKUP, &[], u32::MAX)),
            Some((8, BinRequest::Fatal))
        ));
        assert!(matches!(
            next_frame(&frame(wire::OP_RELOAD, &[], 0)),
            Some((8, BinRequest::Fatal))
        ));
        assert!(matches!(
            next_frame(&frame(wire::OP_RELOAD, &[], wire::MAX_PATH_BYTES + 1)),
            Some((8, BinRequest::Fatal))
        ));
        assert!(matches!(
            next_frame(&frame(wire::OP_KNN_VEC, &[], 0)),
            Some((8, BinRequest::Fatal))
        ));
        assert!(matches!(
            next_frame(&frame(wire::OP_KNN_VEC, &[], wire::MAX_IDS + 1)),
            Some((8, BinRequest::Fatal))
        ));
    }

    #[test]
    fn traced_frames_decode_incrementally() {
        // Hand-rolled traced LOOKUP: flagged header, 24 ctx bytes, payload.
        let mut f = frame(wire::OP_LOOKUP | wire::OP_TRACE_CTX, &[], 2);
        f.extend_from_slice(&0xAABB_CCDD_EEFF_0011_2233_4455_6677_8899u128.to_le_bytes());
        f.extend_from_slice(&0xDEAD_BEEF_CAFE_F00Du64.to_le_bytes());
        f.extend_from_slice(&7u32.to_le_bytes());
        f.extend_from_slice(&9u32.to_le_bytes());
        // Dribble: every strict prefix (including a partial extension) is
        // incomplete; the full frame parses and consumes the extension.
        for cut in 0..f.len() {
            assert!(next_frame(&f[..cut]).is_none(), "cut={cut}");
        }
        match next_frame(&f) {
            Some((consumed, BinRequest::Traced { ctx, parse_us, inner })) => {
                assert_eq!(consumed, f.len());
                assert_eq!(ctx.trace_id, 0xAABB_CCDD_EEFF_0011_2233_4455_6677_8899);
                assert_eq!(ctx.span_id, 0xDEAD_BEEF_CAFE_F00D);
                assert_eq!(parse_us, 0);
                assert_eq!(*inner, BinRequest::Ids { op: wire::OP_LOOKUP, ids: vec![7, 9] });
            }
            other => panic!("{other:?}"),
        }
        // Hostile count with the flag set: fatal from the 8 header bytes
        // alone — the extension is never waited for.
        assert!(matches!(
            next_frame(&frame(wire::OP_LOOKUP | wire::OP_TRACE_CTX, &[], u32::MAX)),
            Some((8, BinRequest::Fatal))
        ));
    }

    #[test]
    fn reload_and_knn_vec_payloads_decode() {
        let f = frame(wire::OP_RELOAD, b"/tmp/m.snap", 11);
        match next_frame(&f) {
            Some((19, BinRequest::Reload { path })) => {
                assert_eq!(path.as_deref(), Some("/tmp/m.snap"))
            }
            other => panic!("{other:?}"),
        }
        // Non-UTF-8 path: request parses, path is None (BAD_FRAME downstream).
        let f = frame(wire::OP_RELOAD, &[0xFF, 0xFE], 2);
        assert!(matches!(next_frame(&f), Some((10, BinRequest::Reload { path: None }))));

        let mut payload = 3u32.to_le_bytes().to_vec();
        for x in [1.0f32, -2.5] {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        let f = frame(wire::OP_KNN_VEC, &payload, 2);
        for cut in 0..f.len() {
            assert!(next_frame(&f[..cut]).is_none(), "cut={cut}");
        }
        match next_frame(&f) {
            Some((20, BinRequest::KnnVec { k, query })) => {
                assert_eq!(k, 3);
                assert_eq!(query, vec![1.0, -2.5]);
            }
            other => panic!("{other:?}"),
        }
    }
}
