//! Hashed timer wheel for connection deadlines (idle/read/write).
//!
//! The reactor owns tens of thousands of mostly-idle connections; a heap of
//! deadlines would pay O(log n) per rearm on every request. The wheel pays
//! O(1): a deadline hashes to `tick % slots`, and advancing the wheel scans
//! only the slots the clock actually crossed. Entries past the wheel's
//! horizon simply survive a lap (their stored tick is in the future when the
//! slot is scanned) and fire on a later pass.
//!
//! Cancellation is lazy: every connection carries a generation counter,
//! bumped whenever its deadline is rearmed, and stale wheel entries are
//! discarded by the caller when the generation no longer matches. Rearming
//! therefore never searches the wheel.

/// One scheduled deadline: fire `token` (a reactor connection slot) at
/// `at` ticks, valid only while the connection's timer generation is `gen`.
#[derive(Debug, Clone, Copy)]
struct Entry {
    at: u64,
    token: usize,
    gen: u64,
}

pub struct TimerWheel {
    slots: Vec<Vec<Entry>>,
    /// Last tick the wheel was advanced to.
    now: u64,
}

impl TimerWheel {
    pub fn new(slots: usize) -> TimerWheel {
        assert!(slots > 0);
        TimerWheel { slots: (0..slots).map(|_| Vec::new()).collect(), now: 0 }
    }

    /// Schedule `(token, gen)` to fire at absolute tick `at` (clamped to the
    /// next tick if already due).
    pub fn schedule(&mut self, at: u64, token: usize, gen: u64) {
        let at = at.max(self.now + 1);
        let slot = (at % self.slots.len() as u64) as usize;
        self.slots[slot].push(Entry { at, token, gen });
    }

    /// Advance the wheel to `now`, appending every due `(token, gen)` to
    /// `due`. Entries scheduled for a later lap stay in their slot.
    pub fn advance(&mut self, now: u64, due: &mut Vec<(usize, u64)>) {
        if now <= self.now {
            return;
        }
        let n = self.slots.len() as u64;
        // If the clock jumped a whole lap or more, every slot is crossed
        // exactly once; otherwise only the ticks in (self.now, now].
        let span = (now - self.now).min(n);
        for i in 1..=span {
            let slot = ((self.now + i) % n) as usize;
            self.slots[slot].retain(|e| {
                if e.at <= now {
                    due.push((e.token, e.gen));
                    false
                } else {
                    true
                }
            });
        }
        self.now = now;
    }

    /// The tick the wheel was last advanced to.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Total scheduled entries (live and stale), for tests and debugging.
    pub fn len(&self) -> usize {
        self.slots.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_at_the_scheduled_tick_not_before() {
        let mut w = TimerWheel::new(8);
        w.schedule(5, 1, 0);
        let mut due = Vec::new();
        w.advance(4, &mut due);
        assert!(due.is_empty(), "{due:?}");
        w.advance(5, &mut due);
        assert_eq!(due, vec![(1, 0)]);
        assert!(w.is_empty());
    }

    #[test]
    fn deadline_past_the_horizon_survives_a_lap() {
        let mut w = TimerWheel::new(4);
        // at=9 hashes to slot 1, which the wheel crosses at tick 1 and 5
        // first — the entry must not fire on those earlier passes.
        w.schedule(9, 2, 7);
        let mut due = Vec::new();
        w.advance(6, &mut due);
        assert!(due.is_empty(), "fired a lap early: {due:?}");
        w.advance(9, &mut due);
        assert_eq!(due, vec![(2, 7)]);
    }

    #[test]
    fn clock_jump_larger_than_the_wheel_drains_everything_due() {
        let mut w = TimerWheel::new(4);
        for t in 0..10u64 {
            w.schedule(t + 1, t as usize, 0);
        }
        let mut due = Vec::new();
        w.advance(100, &mut due);
        assert_eq!(due.len(), 10);
        assert!(w.is_empty());
    }

    #[test]
    fn past_deadlines_clamp_to_the_next_tick() {
        let mut w = TimerWheel::new(8);
        let mut due = Vec::new();
        w.advance(10, &mut due);
        w.schedule(3, 5, 1); // already past: must still fire (at tick 11)
        w.advance(11, &mut due);
        assert_eq!(due, vec![(5, 1)]);
    }

    #[test]
    fn advance_is_monotonic_and_idempotent() {
        let mut w = TimerWheel::new(8);
        w.schedule(2, 0, 0);
        let mut due = Vec::new();
        w.advance(3, &mut due);
        assert_eq!(due.len(), 1);
        due.clear();
        w.advance(3, &mut due); // same tick again: nothing new
        w.advance(1, &mut due); // going backwards: ignored
        assert!(due.is_empty());
        assert_eq!(w.now(), 3);
    }
}
