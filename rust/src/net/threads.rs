//! Blocking thread-per-connection driver (the default).
//!
//! This is the classic shape the listeners had before the reactor existed
//! — one handler thread per accepted connection, blocking reads — lifted
//! behind the [`Service`] trait so it shares the protocol brain (and thus
//! byte-exact responses) with the event-loop driver. What it adds over the
//! old inline loops:
//!
//! * the accept loop survives transient failures (`EMFILE`, `ENFILE`,
//!   `ECONNABORTED`) with backoff instead of silently dying, counting each
//!   into the `accept_errors` STATS field;
//! * graceful shutdown: stop accepting, wait for *busy* requests (not idle
//!   connections) up to the drain deadline, force-close every connection to
//!   unpark blocked reader threads, join them all — no leaked threads.

use super::{sys, Lifecycle, NetConfig, Service, TextAction, MAX_LINE_BYTES};
use crate::obs::Stage;
use crate::serving::wire;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Accept until shutdown, then drain. See the module docs for the policy.
pub fn serve(
    listener: TcpListener,
    svc: Arc<dyn Service>,
    cfg: &NetConfig,
    lifecycle: Arc<Lifecycle>,
) {
    listener.set_nonblocking(true).ok();
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !lifecycle.stopping() {
        match listener.accept() {
            Ok((stream, _)) => {
                // Some platforms leak the listener's nonblocking flag into
                // accepted sockets; this driver needs blocking reads.
                stream.set_nonblocking(false).ok();
                let conn_svc = svc.clone();
                let lc = lifecycle.clone();
                // Builder, not thread::spawn: under a connection flood the
                // OS can refuse new threads, and that must drop one
                // connection, not panic the accept loop.
                let spawned = std::thread::Builder::new()
                    .name("w2k-conn".into())
                    .spawn(move || handle_conn(stream, conn_svc, lc));
                match spawned {
                    Ok(h) => handlers.push(h),
                    Err(e) => {
                        svc.note_accept_error();
                        crate::warn!("cannot spawn handler thread (conn dropped): {e}");
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
                if handlers.len() >= 128 {
                    handlers.retain(|h| !h.is_finished());
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(ref e) if sys::accept_transient(e) => {
                // Out of fds or the peer reset before accept: the listener
                // must outlive the spike. Back off and retry.
                svc.note_accept_error();
                crate::warn!("transient accept error (retrying): {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                svc.note_accept_error();
                crate::warn!("accept error (retrying): {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    drop(listener); // closed: new connections are refused from here on
    let deadline = Instant::now() + Duration::from_millis(cfg.drain_ms);
    while lifecycle.busy() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    if lifecycle.busy() > 0 {
        crate::warn!("drain deadline expired with {} busy requests", lifecycle.busy());
    }
    // Unpark every handler blocked in a read; joining is then prompt.
    lifecycle.close_all();
    for h in handlers {
        let _ = h.join();
    }
}

fn handle_conn(stream: TcpStream, svc: Arc<dyn Service>, lifecycle: Arc<Lifecycle>) {
    let token = lifecycle.track(&stream);
    run_conn(stream, &*svc, &lifecycle);
    if let Some(t) = token {
        lifecycle.untrack(t);
    }
}

/// Per-connection dispatcher: sniff the first byte to pick a protocol.
fn run_conn(stream: TcpStream, svc: &dyn Service, lifecycle: &Lifecycle) {
    let peer = stream.peer_addr().ok();
    crate::debug!("connection from {peer:?}");
    let Ok(clone) = stream.try_clone() else { return };
    let mut reader = BufReader::new(clone);
    let mut writer = stream;
    // Transport-level timing (parse/flush stages). The blocking reads park
    // waiting for the *next request* to arrive at all, which is idle time,
    // not parse work — so each loop below blocks in `fill_buf` first and
    // only then starts the parse timer.
    let obs = svc.obs();
    let timing = obs.as_ref().is_some_and(|o| o.enabled());
    let first = match reader.fill_buf() {
        Ok(buf) if !buf.is_empty() => buf[0],
        _ => return,
    };
    if first == wire::MAGIC[0] {
        let mut magic = [0u8; 4];
        if reader.read_exact(&mut magic).is_err() || magic != wire::MAGIC {
            let _ = writer.write_all(b"ERR bad magic\n");
            return;
        }
        let Some(dim) = svc.hello_dim() else { return };
        let mut hello = Vec::with_capacity(8);
        hello.extend_from_slice(&wire::MAGIC);
        hello.extend_from_slice(&dim.to_le_bytes());
        if writer.write_all(&hello).is_err() {
            return;
        }
        let mut out = Vec::new();
        loop {
            match reader.fill_buf() {
                Ok(buf) if buf.is_empty() => break, // clean EOF between frames
                Err(_) => break,
                Ok(_) => {}
            }
            let t_parse = timing.then(Instant::now);
            let mut req = match wire::read_frame(&mut reader) {
                Ok(Some(req)) => req,
                Ok(None) => break,
                Err(e) => {
                    crate::debug!("binary conn {peer:?} ended: {e}");
                    break;
                }
            };
            let parse = t_parse.map(|t| t.elapsed());
            if let (Some(o), Some(d)) = (&obs, parse) {
                o.record_stage(Stage::Parse, d);
            }
            // Stamp the measured parse time onto a traced request and keep
            // its context: the flush below happens after dispatch finished
            // the span, so it is attributed retroactively via note_flush.
            let mut trace_ctx = None;
            if let wire::BinRequest::Traced { ctx, parse_us, .. } = &mut req {
                *parse_us = parse.map_or(0, |d| d.as_micros() as u64);
                trace_ctx = Some(*ctx);
            }
            out.clear();
            lifecycle.begin_request();
            let close = svc.binary(req, &mut out);
            let t_flush = timing.then(Instant::now);
            let wrote = out.is_empty() || writer.write_all(&out).is_ok();
            if let (Some(o), Some(t)) = (&obs, t_flush) {
                let flushed = t.elapsed();
                o.record_stage(Stage::Flush, flushed);
                if let Some(ctx) = trace_ctx {
                    o.tracer().note_flush(ctx, flushed.as_micros() as u64);
                }
            }
            lifecycle.end_request();
            if close || !wrote {
                break;
            }
        }
    } else {
        let mut line = String::new();
        loop {
            match reader.fill_buf() {
                Ok(buf) if buf.is_empty() => break,
                Err(_) => break,
                Ok(_) => {}
            }
            line.clear();
            let t_parse = timing.then(Instant::now);
            match (&mut reader).take(MAX_LINE_BYTES as u64).read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            if let (Some(o), Some(t)) = (&obs, t_parse) {
                o.record_stage(Stage::Parse, t.elapsed());
            }
            if line.len() >= MAX_LINE_BYTES && !line.ends_with('\n') {
                // Hit the cap mid-line: the rest of the stream is
                // unparseable.
                let _ = writer.write_all(b"ERR line too long\n");
                break;
            }
            lifecycle.begin_request();
            let action = svc.text(&line);
            let t_flush = timing.then(Instant::now);
            let wrote = match &action {
                TextAction::Quit => true,
                TextAction::Reply(r) if r.is_empty() => true,
                TextAction::Reply(r) => writer.write_all(r.as_bytes()).is_ok(),
            };
            if let (Some(o), Some(t)) = (&obs, t_flush) {
                o.record_stage(Stage::Flush, t.elapsed());
            }
            lifecycle.end_request();
            if action == TextAction::Quit || !wrote {
                break;
            }
        }
    }
}
