//! Raw syscall bindings for the event-loop serving core.
//!
//! Vendored extern-C declarations in the same spirit as the mmap wrapper in
//! `snapshot/reader.rs`: no external crates, every `unsafe` confined to this
//! file behind safe wrappers that translate `-1` into
//! [`io::Error::last_os_error`]. Only what the reactor actually needs is
//! bound — epoll (Linux), `poll(2)` (portable unix fallback), `writev` for
//! batched response flushes, and `{get,set}rlimit` so the connection-scaling
//! bench can lift the file-descriptor ceiling.

#![allow(dead_code)] // each platform uses a subset of the bindings

use std::io;

#[cfg(unix)]
pub(crate) mod raw {
    /// One gather segment (`struct iovec`). `writev` never mutates the
    /// buffers, so `base` is `*const`.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct IoVec {
        pub base: *const u8,
        pub len: usize,
    }

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    /// Kernel `struct epoll_event`. x86_64 is the one 64-bit ABI where the
    /// kernel declares it packed (12 bytes); elsewhere natural C layout
    /// applies. Fields are only ever read *by value* (copy), never borrowed,
    /// so the packed layout cannot produce an unaligned reference.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct RLimit {
        pub cur: u64,
        pub max: u64,
    }

    extern "C" {
        pub fn writev(fd: i32, iov: *const IoVec, iovcnt: i32) -> isize;
        pub fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
        pub fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        pub fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }

    #[cfg(target_os = "linux")]
    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout: i32,
        ) -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

// ---- epoll constants (Linux uapi) -----------------------------------------

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;

pub const EPOLL_CTL_ADD: i32 = 1;
pub const EPOLL_CTL_DEL: i32 = 2;
pub const EPOLL_CTL_MOD: i32 = 3;

/// `EPOLL_CLOEXEC` (== `O_CLOEXEC`, octal `02000000`).
pub const EPOLL_CLOEXEC: i32 = 0o2000000;

// ---- poll(2) constants (POSIX) --------------------------------------------

pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;

/// Transient accept(2) failures that must never kill a listener: the
/// connection was reset before accept (`ECONNABORTED`), or the process/
/// system fd table is full (`EMFILE`/`ENFILE`) and will drain. Matched by
/// raw errno because std maps the fd-table errors to an uncategorized kind.
pub fn accept_transient(e: &io::Error) -> bool {
    const EMFILE: i32 = 24;
    const ENFILE: i32 = 23;
    e.kind() == io::ErrorKind::ConnectionAborted
        || e.kind() == io::ErrorKind::Interrupted
        || matches!(e.raw_os_error(), Some(EMFILE) | Some(ENFILE))
}

/// Gather-write `bufs` to `fd`. At most [`MAX_IOV`] segments are submitted
/// per call (the remainder goes on the next readiness cycle).
#[cfg(unix)]
pub fn writev(fd: i32, bufs: &[raw::IoVec]) -> io::Result<usize> {
    let cnt = bufs.len().min(MAX_IOV) as i32;
    let n = unsafe { raw::writev(fd, bufs.as_ptr(), cnt) };
    if n < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(n as usize)
    }
}

/// Segments per writev call; far below every platform's `UIO_MAXIOV`.
pub const MAX_IOV: usize = 64;

/// Raise the soft `RLIMIT_NOFILE` toward `want` (clamped to the hard cap).
/// Returns `(soft before, soft after)`. The connection-scaling bench calls
/// this before opening tens of thousands of sockets.
#[cfg(target_os = "linux")]
pub fn raise_nofile_limit(want: u64) -> io::Result<(u64, u64)> {
    const RLIMIT_NOFILE: i32 = 7;
    let mut rl = raw::RLimit { cur: 0, max: 0 };
    if unsafe { raw::getrlimit(RLIMIT_NOFILE, &mut rl) } != 0 {
        return Err(io::Error::last_os_error());
    }
    let before = rl.cur;
    if rl.cur < want {
        rl.cur = want.min(rl.max);
        if unsafe { raw::setrlimit(RLIMIT_NOFILE, &rl) } != 0 {
            return Err(io::Error::last_os_error());
        }
    }
    Ok((before, rl.cur))
}

#[cfg(not(target_os = "linux"))]
pub fn raise_nofile_limit(_want: u64) -> io::Result<(u64, u64)> {
    Ok((0, 0)) // unsupported: report no change, callers proceed best-effort
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_accept_errors_are_recognized() {
        assert!(accept_transient(&io::Error::from_raw_os_error(24))); // EMFILE
        assert!(accept_transient(&io::Error::from_raw_os_error(23))); // ENFILE
        assert!(accept_transient(&io::Error::new(
            io::ErrorKind::ConnectionAborted,
            "aborted"
        )));
        assert!(!accept_transient(&io::Error::new(
            io::ErrorKind::PermissionDenied,
            "nope"
        )));
    }

    #[cfg(unix)]
    #[test]
    fn writev_gathers_segments() {
        use std::io::Read;
        use std::os::unix::io::AsRawFd;
        use std::os::unix::net::UnixStream;
        let (a, mut b) = UnixStream::pair().unwrap();
        let one = b"hello ";
        let two = b"world";
        let iov = [
            raw::IoVec { base: one.as_ptr(), len: one.len() },
            raw::IoVec { base: two.as_ptr(), len: two.len() },
        ];
        let n = writev(a.as_raw_fd(), &iov).unwrap();
        assert_eq!(n, 11);
        let mut got = [0u8; 11];
        b.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"hello world");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn nofile_limit_is_readable() {
        let (before, after) = raise_nofile_limit(0).unwrap();
        assert!(before > 0);
        assert!(after >= before);
    }
}
