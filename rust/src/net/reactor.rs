//! Readiness-driven event-loop driver.
//!
//! One reactor thread (the caller of [`serve`]) multiplexes the listener and
//! every connection over one [`Poller`](super::poll::Poller): nonblocking
//! sockets, level-triggered readiness, incremental protocol parsing
//! ([`super::parser`]), `writev`-batched response flushes, and deadlines on
//! a [`TimerWheel`](super::timer::TimerWheel). Request *execution* (model
//! code, snapshot reloads) happens on a small handler pool, never on the
//! reactor thread — a slow KNN cannot stall accepts or other connections.
//!
//! ## Pipelining and ordering
//!
//! Binary frames are parsed as fast as they arrive and dispatched
//! concurrently to the handler pool; every request carries a per-connection
//! sequence number and completions are reassembled in sequence order before
//! any byte is written, so pipelined responses always come back in request
//! order. Parsing stops at a terminal frame (QUIT, hostile header) — bytes
//! pipelined *behind* a QUIT are never executed, exactly like the blocking
//! driver which stops reading after it. Text lines are deliberately *not*
//! pipelined (one in flight per connection): the blocking driver reads the
//! next line only after answering the previous one, and a text QUIT must
//! discard — not execute — whatever follows it in the buffer.
//!
//! ## Connection state machine
//!
//! ```text
//!   accept → Sniff ──first byte── Text ──line──▶ dispatch ─▶ reply ─┐
//!              │                   ▲◀──────────── (one at a time) ──┘
//!              │MAGIC
//!              ▼
//!            Binary ──frame──▶ dispatch (pipelined, seq-ordered replies)
//!              │
//!              └─ QUIT / hostile header / bad magic ▶ Discard → close
//! ```
//!
//! Each connection also carries one deadline (idle, read, or write — see
//! `schedule_deadline`); expiry closes it.

use super::parser::{self, LineStep, Sniff};
use super::poll::{Event, Poller};
use super::sys;
use super::timer::TimerWheel;
use super::{Lifecycle, NetConfig, Service, TextAction, MAX_LINE_BYTES};
use crate::obs::{Obs, Stage};
use crate::serving::wire::{self, BinRequest};
use std::collections::{BTreeMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Poller token of the accept socket.
const LISTENER: usize = usize::MAX;
/// Poller token of the handler-pool wakeup pipe.
const WAKER: usize = usize::MAX - 1;
/// Timer-wheel granularity.
const TICK_MS: u64 = 50;
/// Slots on the wheel (one lap = ~51 s; longer deadlines survive laps).
const WHEEL_SLOTS: usize = 1024;

/// One unit of work shipped to the handler pool.
struct Task {
    conn: usize,
    gen: u64,
    seq: u64,
    req: Req,
}

enum Req {
    Text(String),
    Binary(BinRequest),
}

/// One finished request coming back from the pool.
struct Done {
    conn: usize,
    gen: u64,
    seq: u64,
    bytes: Vec<u8>,
    close: bool,
}

/// Reactor ⇄ handler-pool rendezvous.
struct Shared {
    queue: Mutex<VecDeque<Task>>,
    cv: Condvar,
    done: Mutex<Vec<Done>>,
    stop: AtomicBool,
    /// Write half of the wakeup pipe; one byte per completion batch tells
    /// `epoll_wait` to wake early. Nonblocking: a full pipe already means a
    /// wakeup is pending.
    waker: Mutex<UnixStream>,
}

impl Shared {
    fn wake(&self) {
        let mut w = self.waker.lock().expect("waker lock poisoned");
        let _ = w.write(&[1u8]);
    }
}

fn worker(shared: Arc<Shared>, svc: Arc<dyn Service>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().expect("task queue poisoned");
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, Duration::from_millis(100))
                    .expect("task queue poisoned");
                q = guard;
            }
        };
        let mut bytes = Vec::new();
        let close = match task.req {
            Req::Text(line) => match svc.text(&line) {
                TextAction::Reply(r) => {
                    bytes = r.into_bytes();
                    false
                }
                TextAction::Quit => true,
            },
            Req::Binary(req) => svc.binary(req, &mut bytes),
        };
        shared
            .done
            .lock()
            .expect("done list poisoned")
            .push(Done { conn: task.conn, gen: task.gen, seq: task.seq, bytes, close });
        shared.wake();
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Sniff,
    Text,
    Binary,
    /// Terminal: remaining input is read and dropped, pending output still
    /// flushes, then the connection closes.
    Discard,
}

struct Conn {
    stream: TcpStream,
    fd: RawFd,
    /// Guards stale completions/timers after this slab slot is reused.
    gen: u64,
    phase: Phase,
    inbuf: Vec<u8>,
    eof: bool,
    /// Pending response bytes, flushed with `writev` on writability.
    outq: VecDeque<Vec<u8>>,
    /// Bytes of `outq[0]` already written.
    out_head: usize,
    /// Next sequence number to assign to a dispatched request.
    next_seq: u64,
    /// Next sequence number eligible to be written out.
    next_deliver: u64,
    /// Out-of-order completions parked until their turn (pipelining).
    ready: BTreeMap<u64, (Vec<u8>, bool)>,
    /// Requests dispatched but not yet delivered.
    inflight: usize,
    close_after_flush: bool,
    /// Current poller interest, to avoid redundant `EPOLL_CTL_MOD`s.
    int_read: bool,
    int_write: bool,
    /// Matches the newest wheel entry; older entries are stale.
    timer_gen: u64,
}

impl Conn {
    fn queue_out(&mut self, bytes: Vec<u8>) {
        if !bytes.is_empty() {
            self.outq.push_back(bytes);
        }
    }

    fn out_empty(&self) -> bool {
        self.outq.is_empty()
    }
}

struct Reactor {
    poller: Poller,
    listener: Option<TcpListener>,
    slab: Vec<Option<Conn>>,
    free: Vec<usize>,
    wheel: TimerWheel,
    epoch: Instant,
    next_gen: u64,
    next_timer_gen: u64,
    /// Tick until which accepts pause after a transient failure.
    accept_pause_until: u64,
    cfg: NetConfig,
    /// Metrics plane (from [`Service::obs`], kept only when enabled):
    /// parse/flush stage timings, loop-iteration and writev-batch-size
    /// histograms. `None` costs nothing on the hot path.
    obs: Option<Arc<Obs>>,
}

impl Reactor {
    fn tick(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64 / TICK_MS
    }

    fn live_conns(&self) -> usize {
        self.slab.iter().filter(|c| c.is_some()).count()
    }

    fn accept_burst(&mut self, svc: &dyn Service) {
        if self.tick() < self.accept_pause_until {
            return;
        }
        loop {
            let Some(listener) = self.listener.as_ref() else { return };
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let fd = stream.as_raw_fd();
                    self.next_gen += 1;
                    let conn = Conn {
                        stream,
                        fd,
                        gen: self.next_gen,
                        phase: Phase::Sniff,
                        inbuf: Vec::new(),
                        eof: false,
                        outq: VecDeque::new(),
                        out_head: 0,
                        next_seq: 0,
                        next_deliver: 0,
                        ready: BTreeMap::new(),
                        inflight: 0,
                        close_after_flush: false,
                        int_read: true,
                        int_write: false,
                        timer_gen: 0,
                    };
                    let token = match self.free.pop() {
                        Some(t) => {
                            self.slab[t] = Some(conn);
                            t
                        }
                        None => {
                            self.slab.push(Some(conn));
                            self.slab.len() - 1
                        }
                    };
                    if self.poller.register(fd, token, true, false).is_err() {
                        self.slab[token] = None;
                        self.free.push(token);
                        continue;
                    }
                    self.schedule_deadline(token);
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(ref e) if sys::accept_transient(e) => {
                    svc.note_accept_error();
                    crate::warn!("transient accept error (retrying): {e}");
                    self.accept_pause_until = self.tick() + 1;
                    return;
                }
                Err(e) => {
                    svc.note_accept_error();
                    crate::warn!("accept error (retrying): {e}");
                    self.accept_pause_until = self.tick() + 1;
                    return;
                }
            }
        }
    }

    fn close_conn(&mut self, token: usize) {
        if let Some(conn) = self.slab[token].take() {
            let _ = self.poller.deregister(conn.fd);
            self.free.push(token);
            // `conn.stream` drops here, closing the socket.
        }
    }

    /// Pick and arm the connection's single deadline: flushing a response →
    /// write deadline; mid-frame/mid-line with nothing executing → read
    /// deadline; otherwise idle.
    fn schedule_deadline(&mut self, token: usize) {
        let now = self.tick();
        let Some(conn) = self.slab[token].as_mut() else { return };
        let ms = if !conn.out_empty() {
            self.cfg.write_timeout_ms
        } else if !conn.inbuf.is_empty() && conn.inflight == 0 {
            self.cfg.read_timeout_ms
        } else {
            self.cfg.idle_timeout_ms
        };
        self.next_timer_gen += 1;
        conn.timer_gen = self.next_timer_gen;
        self.wheel.schedule(now + (ms / TICK_MS).max(1), token, conn.timer_gen);
    }

    /// Drain the socket. Returns false when the connection died.
    fn read_conn(&mut self, token: usize) -> bool {
        let Some(conn) = self.slab[token].as_mut() else { return true };
        // Text backpressure: while a line executes, leave bytes in the
        // kernel buffer (interest is also dropped; see `rearm`).
        if conn.phase == Phase::Text && conn.inflight > 0 {
            return true;
        }
        let mut chunk = [0u8; 16 * 1024];
        loop {
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.eof = true;
                    return true;
                }
                Ok(n) => {
                    if conn.phase != Phase::Discard {
                        conn.inbuf.extend_from_slice(&chunk[..n]);
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => return true,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
    }

    /// Parse whatever is buffered, dispatching complete requests. Returns
    /// false when the connection must close *now*, unflushed (the blocking
    /// driver's silent-close cases).
    fn parse_conn(
        &mut self,
        token: usize,
        shared: &Shared,
        svc: &dyn Service,
        lifecycle: &Lifecycle,
    ) -> bool {
        loop {
            let Some(conn) = self.slab[token].as_mut() else { return true };
            match conn.phase {
                Phase::Sniff => match parser::sniff(&conn.inbuf) {
                    Sniff::Incomplete => {
                        if conn.eof {
                            if conn.inbuf.is_empty() {
                                return false;
                            }
                            // A MAGIC prefix cut off by EOF: the blocking
                            // driver's magic read_exact fails the same way.
                            conn.queue_out(b"ERR bad magic\n".to_vec());
                            conn.close_after_flush = true;
                            conn.phase = Phase::Discard;
                        }
                        return true;
                    }
                    Sniff::Text => conn.phase = Phase::Text,
                    Sniff::Binary => {
                        conn.inbuf.drain(..wire::MAGIC.len());
                        let Some(dim) = svc.hello_dim() else { return false };
                        let mut hello = Vec::with_capacity(8);
                        hello.extend_from_slice(&wire::MAGIC);
                        hello.extend_from_slice(&dim.to_le_bytes());
                        conn.queue_out(hello);
                        conn.phase = Phase::Binary;
                    }
                    Sniff::BadMagic => {
                        conn.queue_out(b"ERR bad magic\n".to_vec());
                        conn.close_after_flush = true;
                        conn.phase = Phase::Discard;
                    }
                },
                Phase::Text => {
                    if conn.inflight > 0 {
                        return true; // one text line in flight at a time
                    }
                    let t_parse = self.obs.as_ref().map(|_| Instant::now());
                    match parser::next_line(&conn.inbuf, MAX_LINE_BYTES) {
                        LineStep::Incomplete => {
                            if conn.eof && !conn.inbuf.is_empty() {
                                // EOF-truncated tail: read_line would still
                                // yield it, so dispatch it.
                                let LineStep::Line { text, .. } = parser::eof_line(&conn.inbuf)
                                else {
                                    return false;
                                };
                                conn.inbuf.clear();
                                let Some(text) = text else { return false };
                                dispatch(conn, token, shared, lifecycle, Req::Text(text));
                            }
                            return true;
                        }
                        LineStep::TooLong => {
                            conn.queue_out(b"ERR line too long\n".to_vec());
                            conn.close_after_flush = true;
                            conn.phase = Phase::Discard;
                        }
                        LineStep::Line { consumed, text } => {
                            conn.inbuf.drain(..consumed);
                            if let (Some(o), Some(t)) = (&self.obs, t_parse) {
                                o.record_stage(Stage::Parse, t.elapsed());
                            }
                            // Invalid UTF-8 closes silently, like the
                            // blocking read_line erroring out.
                            let Some(text) = text else { return false };
                            dispatch(conn, token, shared, lifecycle, Req::Text(text));
                        }
                    }
                }
                Phase::Binary => {
                    let t_parse = self.obs.as_ref().map(|_| Instant::now());
                    match parser::next_frame(&conn.inbuf) {
                        None => return true,
                        Some((consumed, mut req)) => {
                            conn.inbuf.drain(..consumed);
                            if let (Some(o), Some(t)) = (&self.obs, t_parse) {
                                o.record_stage(Stage::Parse, t.elapsed());
                            }
                            // Stamp the measured parse time onto a traced
                            // request. No note_flush counterpart here: this
                            // driver flushes whole writev batches, so flush
                            // time has no per-request attribution.
                            if let wire::BinRequest::Traced { parse_us, .. } = &mut req {
                                *parse_us = t_parse.map_or(0, |t| t.elapsed().as_micros() as u64);
                            }
                            let terminal = req.is_terminal();
                            dispatch(conn, token, shared, lifecycle, Req::Binary(req));
                            if terminal {
                                conn.phase = Phase::Discard;
                            }
                        }
                    }
                }
                Phase::Discard => {
                    conn.inbuf.clear();
                    return true;
                }
            }
        }
    }

    /// writev as much pending output as the socket takes. Returns false
    /// when the connection died.
    fn flush_conn(&mut self, token: usize) -> bool {
        let Some(conn) = self.slab[token].as_mut() else { return true };
        while !conn.outq.is_empty() {
            let mut iov = Vec::with_capacity(conn.outq.len().min(sys::MAX_IOV));
            for (i, buf) in conn.outq.iter().enumerate().take(sys::MAX_IOV) {
                let off = if i == 0 { conn.out_head } else { 0 };
                iov.push(sys::raw::IoVec { base: buf[off..].as_ptr(), len: buf.len() - off });
            }
            let t_flush = self.obs.as_ref().map(|_| Instant::now());
            match sys::writev(conn.fd, &iov) {
                Ok(0) => return false,
                Ok(mut n) => {
                    if let (Some(o), Some(t)) = (&self.obs, t_flush) {
                        o.record_stage(Stage::Flush, t.elapsed());
                        o.record_writev_batch(iov.len());
                    }
                    while n > 0 {
                        let avail = conn.outq[0].len() - conn.out_head;
                        if n >= avail {
                            conn.outq.pop_front();
                            conn.out_head = 0;
                            n -= avail;
                        } else {
                            conn.out_head += n;
                            n = 0;
                        }
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => return true,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        true
    }

    /// Post-activity bookkeeping: finish EOF, flush, close when drained,
    /// recompute poller interest, rearm the deadline.
    fn settle(&mut self, token: usize) {
        {
            let Some(conn) = self.slab[token].as_mut() else { return };
            // EOF with nothing left to execute: whatever is buffered is an
            // incomplete frame the blocking driver would also abandon.
            if conn.eof && conn.inflight == 0 {
                conn.close_after_flush = true;
            }
        }
        if !self.flush_conn(token) {
            self.close_conn(token);
            return;
        }
        let Some(conn) = self.slab[token].as_mut() else { return };
        if conn.out_empty() && conn.close_after_flush && conn.inflight == 0 {
            self.close_conn(token);
            return;
        }
        let want_read = !(conn.phase == Phase::Text && conn.inflight > 0);
        let want_write = !conn.out_empty();
        if want_read != conn.int_read || want_write != conn.int_write {
            conn.int_read = want_read;
            conn.int_write = want_write;
            let (fd, r, w) = (conn.fd, want_read, want_write);
            if self.poller.rearm(fd, token, r, w).is_err() {
                self.close_conn(token);
                return;
            }
        }
        self.schedule_deadline(token);
    }

    fn handle_conn_event(
        &mut self,
        ev: Event,
        shared: &Shared,
        svc: &dyn Service,
        lifecycle: &Lifecycle,
    ) {
        let token = ev.token;
        if self.slab.get(token).map(|c| c.is_none()).unwrap_or(true) {
            return; // already closed this cycle
        }
        if ev.readable && !self.read_conn(token) {
            self.close_conn(token);
            return;
        }
        if !self.parse_conn(token, shared, svc, lifecycle) {
            self.close_conn(token);
            return;
        }
        self.settle(token);
    }

    /// Deliver finished requests in per-connection sequence order.
    fn process_done(&mut self, shared: &Shared, svc: &dyn Service, lifecycle: &Lifecycle) {
        let batch: Vec<Done> =
            std::mem::take(&mut *shared.done.lock().expect("done list poisoned"));
        let mut touched = Vec::new();
        for done in batch {
            lifecycle.end_request();
            let Some(conn) = self.slab.get_mut(done.conn).and_then(Option::as_mut) else {
                continue; // connection died while the request executed
            };
            if conn.gen != done.gen {
                continue; // slot was reused: completion belongs to a dead conn
            }
            conn.ready.insert(done.seq, (done.bytes, done.close));
            while let Some((bytes, close)) = conn.ready.remove(&conn.next_deliver) {
                conn.next_deliver += 1;
                conn.inflight -= 1;
                conn.queue_out(bytes);
                if close {
                    conn.close_after_flush = true;
                    conn.phase = Phase::Discard;
                }
            }
            if !touched.contains(&done.conn) {
                touched.push(done.conn);
            }
        }
        for token in touched {
            // A text connection may have the next line already buffered.
            if !self.parse_conn(token, shared, svc, lifecycle) {
                self.close_conn(token);
                continue;
            }
            self.settle(token);
        }
    }

    fn fire_timers(&mut self, due: &mut Vec<(usize, u64)>) {
        due.clear();
        let now = self.tick();
        self.wheel.advance(now, due);
        for &(token, tgen) in due.iter() {
            let expired = self.slab.get(token).and_then(Option::as_ref).map(|c| {
                // Only the *newest* deadline counts; rearms invalidate
                // older wheel entries lazily.
                c.timer_gen == tgen
            });
            if expired == Some(true) {
                crate::debug!("conn deadline expired; closing");
                self.close_conn(token);
            }
        }
    }
}

fn dispatch(conn: &mut Conn, token: usize, shared: &Shared, lifecycle: &Lifecycle, req: Req) {
    let seq = conn.next_seq;
    conn.next_seq += 1;
    conn.inflight += 1;
    lifecycle.begin_request();
    shared
        .queue
        .lock()
        .expect("task queue poisoned")
        .push_back(Task { conn: token, gen: conn.gen, seq, req });
    shared.cv.notify_one();
}

/// Run the event loop until `lifecycle` begins shutdown, then drain
/// in-flight requests (up to `cfg.drain_ms`), close every connection, and
/// join the handler pool. Falls back to the blocking driver if no poller
/// can be created.
pub fn serve(
    listener: TcpListener,
    svc: Arc<dyn Service>,
    cfg: &NetConfig,
    lifecycle: Arc<Lifecycle>,
) {
    let poller = match Poller::new() {
        Ok(p) => p,
        Err(e) => {
            crate::warn!("event-loop poller unavailable ({e}); falling back to threads driver");
            return super::threads::serve(listener, svc, cfg, lifecycle);
        }
    };
    listener.set_nonblocking(true).ok();
    let Ok((waker_rx, waker_tx)) = UnixStream::pair() else {
        crate::warn!("wakeup pipe unavailable; falling back to threads driver");
        return super::threads::serve(listener, svc, cfg, lifecycle);
    };
    waker_rx.set_nonblocking(true).ok();
    waker_tx.set_nonblocking(true).ok();

    let shared = Arc::new(Shared {
        queue: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        done: Mutex::new(Vec::new()),
        stop: AtomicBool::new(false),
        waker: Mutex::new(waker_tx),
    });
    let workers: Vec<_> = (0..cfg.handlers.max(1))
        .map(|_| {
            let shared = shared.clone();
            let svc = svc.clone();
            std::thread::spawn(move || worker(shared, svc))
        })
        .collect();

    let mut r = Reactor {
        poller,
        listener: Some(listener),
        slab: Vec::new(),
        free: Vec::new(),
        wheel: TimerWheel::new(WHEEL_SLOTS),
        epoch: Instant::now(),
        next_gen: 0,
        next_timer_gen: 0,
        accept_pause_until: 0,
        cfg: *cfg,
        obs: svc.obs().filter(|o| o.enabled()),
    };
    if let Some(l) = r.listener.as_ref() {
        if r.poller.register(l.as_raw_fd(), LISTENER, true, false).is_err() {
            crate::warn!("cannot register listener; falling back to threads driver");
            let listener = r.listener.take().expect("listener present");
            shared.stop.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
            for w in workers {
                let _ = w.join();
            }
            return super::threads::serve(listener, svc, cfg, lifecycle);
        }
    }
    r.poller.register(waker_rx.as_raw_fd(), WAKER, true, false).ok();

    let mut events: Vec<Event> = Vec::new();
    let mut due: Vec<(usize, u64)> = Vec::new();
    let mut drain_deadline: Option<Instant> = None;
    loop {
        // One histogram sample per event-loop lap (includes the bounded
        // `poller.wait`); a fat tail here means a handler is running on the
        // reactor thread or a parse/flush is degenerate.
        let iter_t0 = r.obs.as_ref().map(|_| Instant::now());
        r.fire_timers(&mut due);

        if lifecycle.stopping() {
            if let Some(l) = r.listener.take() {
                let _ = r.poller.deregister(l.as_raw_fd());
                drop(l); // refuse new connections from here on
                drain_deadline = Some(Instant::now() + Duration::from_millis(r.cfg.drain_ms));
                // Idle connections don't gate the drain: close them now.
                let idle: Vec<usize> = r
                    .slab
                    .iter()
                    .enumerate()
                    .filter_map(|(t, c)| {
                        let c = c.as_ref()?;
                        (c.inflight == 0 && c.out_empty()).then_some(t)
                    })
                    .collect();
                for t in idle {
                    r.close_conn(t);
                }
            }
            let expired = drain_deadline.map(|d| Instant::now() >= d).unwrap_or(true);
            if r.live_conns() == 0 || expired {
                break;
            }
        }

        events.clear();
        if let Err(e) = r.poller.wait(&mut events, 10) {
            crate::warn!("poller wait failed: {e}");
            break;
        }
        for &ev in events.iter() {
            match ev.token {
                LISTENER => r.accept_burst(&*svc),
                WAKER => {
                    let mut sink = [0u8; 64];
                    while matches!((&waker_rx).read(&mut sink), Ok(n) if n > 0) {}
                }
                _ => r.handle_conn_event(ev, &shared, &*svc, &lifecycle),
            }
        }
        r.process_done(&shared, &*svc, &lifecycle);
        if let (Some(o), Some(t)) = (&r.obs, iter_t0) {
            o.record_loop_iter(t.elapsed());
        }
    }

    // Force-close whatever the drain left behind, then stop the pool.
    let remaining: Vec<usize> =
        (0..r.slab.len()).filter(|&t| r.slab[t].is_some()).collect();
    if !remaining.is_empty() {
        crate::warn!("drain deadline expired with {} open connections", remaining.len());
    }
    for t in remaining {
        r.close_conn(t);
    }
    shared.stop.store(true, Ordering::SeqCst);
    shared.cv.notify_all();
    for w in workers {
        let _ = w.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};
    use std::sync::atomic::AtomicU64;

    /// Minimal protocol brain: text echoes, binary echoes op/ids back as
    /// `status=op count=len` — enough to exercise ordering and lifecycle.
    struct EchoSvc {
        accept_errors: AtomicU64,
    }

    impl Service for EchoSvc {
        fn hello_dim(&self) -> Option<u32> {
            Some(4)
        }

        fn text(&self, line: &str) -> TextAction {
            let t = line.trim();
            if t == "QUIT" {
                TextAction::Quit
            } else if t.is_empty() {
                TextAction::Reply(String::new())
            } else {
                TextAction::Reply(format!("echo {t}\n"))
            }
        }

        fn binary(&self, req: BinRequest, out: &mut Vec<u8>) -> bool {
            match req {
                BinRequest::Fatal => {
                    out.extend_from_slice(&wire::STATUS_BAD_FRAME.to_le_bytes());
                    out.extend_from_slice(&0u32.to_le_bytes());
                    true
                }
                BinRequest::Ids { op: wire::OP_QUIT, .. } => true,
                BinRequest::Ids { op, ids } => {
                    out.extend_from_slice(&op.to_le_bytes());
                    out.extend_from_slice(&(ids.len() as u32).to_le_bytes());
                    false
                }
                _ => {
                    out.extend_from_slice(&wire::STATUS_OK.to_le_bytes());
                    out.extend_from_slice(&0u32.to_le_bytes());
                    false
                }
            }
        }

        fn note_accept_error(&self) {
            self.accept_errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn start() -> (String, Arc<Lifecycle>, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let lifecycle = Lifecycle::new();
        let lc = lifecycle.clone();
        let svc: Arc<dyn Service> = Arc::new(EchoSvc { accept_errors: AtomicU64::new(0) });
        let cfg = NetConfig { handlers: 2, drain_ms: 500, ..NetConfig::default() };
        let h = std::thread::spawn(move || serve(listener, svc, &cfg, lc));
        (addr, lifecycle, h)
    }

    #[test]
    fn text_round_trip_and_graceful_shutdown() {
        let (addr, lifecycle, h) = start();
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"hello\n").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line, "echo hello\n");
        s.write_all(b"QUIT\n").unwrap();
        lifecycle.begin_shutdown();
        h.join().unwrap(); // serve() returns: no leaked reactor/handlers
    }

    #[test]
    fn pipelined_binary_frames_answer_in_order() {
        let (addr, lifecycle, h) = start();
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&wire::MAGIC).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut hello = [0u8; 8];
        r.read_exact(&mut hello).unwrap();
        assert_eq!(&hello[..4], &wire::MAGIC);
        // Three frames in one write; replies must come back 1-id, 2-id,
        // 3-id in that order regardless of handler scheduling.
        let mut burst = Vec::new();
        for n in 1u32..=3 {
            burst.extend_from_slice(&wire::OP_LOOKUP.to_le_bytes());
            burst.extend_from_slice(&n.to_le_bytes());
            for id in 0..n {
                burst.extend_from_slice(&id.to_le_bytes());
            }
        }
        s.write_all(&burst).unwrap();
        for n in 1u32..=3 {
            let mut resp = [0u8; 8];
            r.read_exact(&mut resp).unwrap();
            assert_eq!(u32::from_le_bytes(resp[..4].try_into().unwrap()), wire::OP_LOOKUP);
            assert_eq!(u32::from_le_bytes(resp[4..].try_into().unwrap()), n, "order broke");
        }
        lifecycle.begin_shutdown();
        h.join().unwrap();
    }

    #[test]
    fn dribbled_bytes_parse_once_complete() {
        let (addr, lifecycle, h) = start();
        let mut s = TcpStream::connect(&addr).unwrap();
        for b in b"ST" {
            s.write_all(&[*b]).unwrap();
            std::thread::sleep(Duration::from_millis(5));
        }
        s.write_all(b"ATS\n").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line, "echo STATS\n");
        lifecycle.begin_shutdown();
        h.join().unwrap();
    }

    #[test]
    fn bad_magic_is_rejected() {
        let (addr, lifecycle, h) = start();
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut bad = wire::MAGIC;
        bad[1] ^= 0xFF;
        s.write_all(&bad).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line, "ERR bad magic\n");
        // Connection is closed after the error line.
        let mut rest = String::new();
        assert_eq!(r.read_line(&mut rest).unwrap(), 0);
        lifecycle.begin_shutdown();
        h.join().unwrap();
    }
}
