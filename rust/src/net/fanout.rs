//! Concurrent scatter-gather fan-out for the cluster router.
//!
//! The threaded router scatters a multi-shard request by spawning one
//! scoped thread per shard, each doing a blocking round-trip on that
//! shard's pooled connection. This module replaces the thread fan-out with
//! one event loop: write every request frame, then multiplex all the
//! replies on a single [`Poller`](super::poll::Poller) — in-flight on every
//! shard at once, zero thread spawns per request.
//!
//! Scope: one request frame, one response frame, per pooled connection. The
//! caller (the router) still owns replica choice, slot locking, health
//! accounting, and fallback — a connection that fails here is marked
//! broken (so the pool reconnects it later) and the router retries that
//! shard through the ordinary blocking failover path. Response *decoding*
//! reuses the exact header/payload layout the [`BinaryClient`] readers
//! expect; only the transport scheduling differs.

use super::poll::{Event, Poller};
use crate::serving::wire::{self, WireError};
use crate::serving::BinaryClient;
use std::io::{self, Read, Write};
use std::os::unix::io::AsRawFd;
use std::time::{Duration, Instant};

/// Expected response payload layout for one exchange.
#[derive(Debug, Clone, Copy)]
pub enum Shape {
    /// `count` rows of `dim` f32s (OP_LOOKUP).
    Rows { dim: usize },
    /// `count` (u32 id, f32 score) pairs (OP_KNN / OP_KNN_VEC).
    Neighbors,
}

/// A decoded OK response.
#[derive(Debug)]
pub enum Payload {
    Rows(Vec<Vec<f32>>),
    Neighbors(Vec<(u32, f32)>),
}

/// One request to put in flight on a pooled connection. The caller must
/// have checked [`BinaryClient::fanout_ready`] — a dirty read buffer or a
/// poisoned transport cannot be multiplexed safely.
pub struct Exchange<'a> {
    pub client: &'a mut BinaryClient,
    pub frame: Vec<u8>,
    pub shape: Shape,
}

struct JobState {
    buf: Vec<u8>,
    /// Total bytes wanted: 8 until the header arrives, then 8 + payload.
    need: usize,
    header_parsed: bool,
    status: u32,
    count: usize,
    done: Option<Result<Payload, WireError>>,
}

impl JobState {
    fn new() -> JobState {
        JobState { buf: Vec::new(), need: 8, header_parsed: false, status: 0, count: 0, done: None }
    }
}

/// Write every frame, then multiplex all replies until done or `deadline`
/// elapses. Returns one result per job, in job order. Transport failures
/// (including deadline expiry) mark that job's client broken; server
/// status errors leave the connection clean, exactly like
/// `BinaryClient::roundtrip`.
pub fn exchange_all(mut jobs: Vec<Exchange<'_>>, deadline: Duration) -> Vec<Result<Payload, WireError>> {
    let mut states: Vec<JobState> = jobs.iter().map(|_| JobState::new()).collect();

    // Phase 1: blocking writes. Frames are small (ids / one query vector)
    // and the sockets keep their configured write timeouts.
    for (job, state) in jobs.iter_mut().zip(states.iter_mut()) {
        let frame = std::mem::take(&mut job.frame);
        if let Err(e) = job.client.stream().write_all(&frame) {
            job.client.mark_broken();
            state.done = Some(Err(wire::classify(e)));
        }
    }

    // Phase 2: multiplexed reads.
    match Poller::new() {
        Ok(poller) => multiplex_reads(&mut jobs, &mut states, poller, deadline),
        // No poller (fd exhaustion): degrade to sequential blocking reads —
        // still correct, just serial.
        Err(_) => {
            for (job, state) in jobs.iter_mut().zip(states.iter_mut()) {
                if state.done.is_some() {
                    continue;
                }
                blocking_read(job, state);
            }
        }
    }

    states
        .into_iter()
        .map(|s| s.done.unwrap_or(Err(WireError::TimedOut)))
        .collect()
}

fn multiplex_reads(
    jobs: &mut [Exchange<'_>],
    states: &mut [JobState],
    mut poller: Poller,
    deadline: Duration,
) {
    let start = Instant::now();
    let mut pending = 0usize;
    for (i, (job, state)) in jobs.iter_mut().zip(states.iter_mut()).enumerate() {
        if state.done.is_some() {
            continue;
        }
        let stream = job.client.stream();
        if stream.set_nonblocking(true).is_err()
            || poller.register(stream.as_raw_fd(), i, true, false).is_err()
        {
            job.client.mark_broken();
            state.done = Some(Err(WireError::TimedOut));
            continue;
        }
        pending += 1;
    }
    let mut events: Vec<Event> = Vec::new();
    while pending > 0 {
        let remain = deadline.saturating_sub(start.elapsed());
        if remain.is_zero() {
            break;
        }
        events.clear();
        let timeout = (remain.as_millis() as i64).clamp(1, 100) as i32;
        if poller.wait(&mut events, timeout).is_err() {
            break;
        }
        for ev in &events {
            let i = ev.token;
            let (job, state) = (&mut jobs[i], &mut states[i]);
            if state.done.is_some() {
                continue;
            }
            step_read(job, state);
            if state.done.is_some() {
                let _ = poller.deregister(job.client.stream().as_raw_fd());
                pending -= 1;
            }
        }
    }
    // Deadline leftovers: the stream holds (or will hold) a half-read
    // late reply — poison so the pool reconnects before reusing it.
    for (job, state) in jobs.iter_mut().zip(states.iter_mut()) {
        if state.done.is_none() {
            let _ = poller.deregister(job.client.stream().as_raw_fd());
            job.client.mark_broken();
            state.done = Some(Err(WireError::TimedOut));
        }
        let _ = job.client.stream().set_nonblocking(false);
    }
}

/// Nonblocking read step: pull bytes toward `need`, parse the header when
/// it lands, finish when the payload is complete.
fn step_read(job: &mut Exchange<'_>, state: &mut JobState) {
    let mut chunk = [0u8; 16 * 1024];
    loop {
        let want = state.need - state.buf.len();
        if want == 0 {
            break;
        }
        // Never read past this response: the pooled connection must stay
        // frame-aligned for its next (blocking) user.
        let cap = want.min(chunk.len());
        match job.client.stream().read(&mut chunk[..cap]) {
            Ok(0) => {
                job.client.mark_broken();
                state.done = Some(Err(WireError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid-response",
                ))));
                return;
            }
            Ok(n) => {
                state.buf.extend_from_slice(&chunk[..n]);
                if !state.header_parsed && state.buf.len() >= 8 {
                    parse_header(job, state);
                }
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => {
                job.client.mark_broken();
                state.done = Some(Err(wire::classify(e)));
                return;
            }
        }
    }
    if state.header_parsed && state.buf.len() == state.need {
        finish(job, state);
    }
}

/// Blocking fallback read (sockets still in blocking mode, io timeouts
/// apply): header, then payload, then decode.
fn blocking_read(job: &mut Exchange<'_>, state: &mut JobState) {
    let mut stream = job.client.stream();
    let mut header = [0u8; 8];
    if let Err(e) = stream.read_exact(&mut header) {
        job.client.mark_broken();
        state.done = Some(Err(wire::classify(e)));
        return;
    }
    state.buf.extend_from_slice(&header);
    parse_header(job, state);
    while state.buf.len() < state.need {
        let mut chunk = vec![0u8; state.need - state.buf.len()];
        if let Err(e) = stream.read_exact(&mut chunk) {
            job.client.mark_broken();
            state.done = Some(Err(wire::classify(e)));
            return;
        }
        state.buf.extend_from_slice(&chunk);
    }
    finish(job, state);
}

fn parse_header(job: &Exchange<'_>, state: &mut JobState) {
    state.status = u32::from_le_bytes(state.buf[..4].try_into().expect("8-byte header"));
    state.count = u32::from_le_bytes(state.buf[4..8].try_into().expect("8-byte header")) as usize;
    state.header_parsed = true;
    // Error frames carry no payload regardless of shape.
    let payload = if state.status != wire::STATUS_OK {
        0
    } else {
        match job.shape {
            Shape::Rows { dim } => state.count * dim * 4,
            Shape::Neighbors => state.count * 8,
        }
    };
    state.need = 8 + payload;
}

fn finish(job: &Exchange<'_>, state: &mut JobState) {
    if state.status != wire::STATUS_OK {
        // A complete error frame: the server answered, the connection is
        // clean and stays pooled.
        state.done = Some(Err(WireError::Status(state.status)));
        return;
    }
    let body = &state.buf[8..];
    let payload = match job.shape {
        Shape::Rows { dim } => {
            let mut rows = Vec::with_capacity(state.count);
            for r in 0..state.count {
                let row = body[r * dim * 4..(r + 1) * dim * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
                    .collect();
                rows.push(row);
            }
            Payload::Rows(rows)
        }
        Shape::Neighbors => {
            let pairs = body
                .chunks_exact(8)
                .map(|c| {
                    (
                        u32::from_le_bytes(c[..4].try_into().expect("8-byte pair")),
                        f32::from_le_bytes(c[4..].try_into().expect("8-byte pair")),
                    )
                })
                .collect();
            Payload::Neighbors(pairs)
        }
    };
    state.done = Some(Ok(payload));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::wire::{put_f32s, put_u32};
    use std::net::TcpListener;

    /// A hand-rolled shard stub: accepts one binary connection, answers
    /// each LOOKUP frame with `count` rows of `dim` f32s (value = id).
    fn stub_shard(dim: usize) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let Ok((mut s, _)) = listener.accept() else { return };
            let mut magic = [0u8; 4];
            use std::io::{Read, Write};
            if s.read_exact(&mut magic).is_err() {
                return;
            }
            let mut hello = wire::MAGIC.to_vec();
            hello.extend_from_slice(&(dim as u32).to_le_bytes());
            s.write_all(&hello).unwrap();
            let mut reader = std::io::BufReader::new(s.try_clone().unwrap());
            loop {
                let mut head = [0u8; 8];
                if reader.read_exact(&mut head).is_err() {
                    return;
                }
                let count = u32::from_le_bytes(head[4..].try_into().unwrap()) as usize;
                let mut ids = vec![0u8; count * 4];
                if reader.read_exact(&mut ids).is_err() {
                    return;
                }
                let mut out = Vec::new();
                put_u32(&mut out, wire::STATUS_OK);
                put_u32(&mut out, count as u32);
                for c in ids.chunks_exact(4) {
                    let id = u32::from_le_bytes(c.try_into().unwrap());
                    put_f32s(&mut out, &vec![id as f32; dim]);
                }
                if s.write_all(&out).is_err() {
                    return;
                }
            }
        });
        addr
    }

    #[test]
    fn multiplexed_lookups_decode_per_shard() {
        let dim = 3;
        let a = stub_shard(dim);
        let b = stub_shard(dim);
        let mut ca = BinaryClient::connect(&a).unwrap();
        let mut cb = BinaryClient::connect(&b).unwrap();
        assert!(ca.fanout_ready() && cb.fanout_ready());
        let jobs = vec![
            Exchange {
                client: &mut ca,
                frame: wire::encode_ids_frame(wire::OP_LOOKUP, &[1, 2]),
                shape: Shape::Rows { dim },
            },
            Exchange {
                client: &mut cb,
                frame: wire::encode_ids_frame(wire::OP_LOOKUP, &[7]),
                shape: Shape::Rows { dim },
            },
        ];
        let results = exchange_all(jobs, Duration::from_secs(5));
        match &results[0] {
            Ok(Payload::Rows(rows)) => {
                assert_eq!(rows.len(), 2);
                assert_eq!(rows[0], vec![1.0; dim]);
                assert_eq!(rows[1], vec![2.0; dim]);
            }
            other => panic!("{other:?}"),
        }
        match &results[1] {
            Ok(Payload::Rows(rows)) => assert_eq!(rows[0], vec![7.0; dim]),
            other => panic!("{other:?}"),
        }
        // Connections come back blocking and clean: pooled reuse works.
        assert!(ca.fanout_ready() && cb.fanout_ready());
        assert_eq!(ca.lookup(&[4]).unwrap()[0], vec![4.0; dim]);
    }

    #[test]
    fn dead_peer_breaks_only_its_own_job() {
        let dim = 2;
        let live = stub_shard(dim);
        // A listener that accepts the handshake then hangs up.
        let dead_listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let dead = dead_listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let Ok((mut s, _)) = dead_listener.accept() else { return };
            use std::io::{Read, Write};
            let mut magic = [0u8; 4];
            s.read_exact(&mut magic).ok();
            let mut hello = wire::MAGIC.to_vec();
            hello.extend_from_slice(&(dim as u32).to_le_bytes());
            s.write_all(&hello).ok();
            // Read one frame header then drop the connection mid-response.
            let mut head = [0u8; 8];
            s.read_exact(&mut head).ok();
        });
        let mut ca = BinaryClient::connect(&live).unwrap();
        let mut cb = BinaryClient::connect(&dead).unwrap();
        let jobs = vec![
            Exchange {
                client: &mut ca,
                frame: wire::encode_ids_frame(wire::OP_LOOKUP, &[5]),
                shape: Shape::Rows { dim },
            },
            Exchange {
                client: &mut cb,
                frame: wire::encode_ids_frame(wire::OP_LOOKUP, &[6]),
                shape: Shape::Rows { dim },
            },
        ];
        let results = exchange_all(jobs, Duration::from_secs(5));
        assert!(matches!(&results[0], Ok(Payload::Rows(_))), "{:?}", results[0]);
        assert!(results[1].is_err(), "dead peer must fail");
        assert!(ca.fanout_ready(), "healthy connection stays pooled");
        assert!(!cb.fanout_ready(), "failed connection is poisoned");
    }
}
