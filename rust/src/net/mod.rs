//! Network serving core: one listener abstraction, two drivers.
//!
//! Every listener in the repo (single-node coordinator, cluster router)
//! speaks the same two protocols — line-oriented text and the length-framed
//! binary protocol from [`crate::serving::wire`] — sniffed from the first
//! byte of each connection. This module splits *what the server answers*
//! from *how connections are driven*:
//!
//! * [`Service`] is the protocol brain: given one text line or one decoded
//!   binary frame, produce the response bytes. The coordinator and the
//!   router each implement it once, and both drivers call the same impl, so
//!   driver choice can never change a response byte.
//! * [`threads`] is the classic blocking driver: thread per connection,
//!   blocking reads. Simple, debuggable, the default.
//! * [`reactor`] is the event-loop driver: one reactor thread multiplexing
//!   every connection over epoll (`poll(2)` off Linux), nonblocking sockets,
//!   a per-connection incremental parser ([`parser`]), request pipelining on
//!   the binary protocol, `writev`-batched responses, and idle/read/write
//!   deadlines kept on a [`timer`] wheel.
//!
//! The driver is picked by `[net] driver = "threads" | "epoll"` in the
//! experiment config (default `threads`). Both drivers share the
//! accept-backoff policy (transient `accept(2)` failures back off and
//! retry, counted in the `accept_errors` STATS field, never killing the
//! listener) and the graceful-shutdown protocol driven by [`Lifecycle`]:
//! stop accepting, drain in-flight requests up to a deadline, close every
//! connection, join every thread.

pub mod parser;
pub mod sys;
pub mod threads;
pub mod timer;

#[cfg(unix)]
pub mod fanout;
#[cfg(unix)]
pub mod poll;
#[cfg(unix)]
pub mod reactor;

use crate::serving::wire::BinRequest;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Text lines above this many bytes poison the stream (`ERR line too
/// long\n`, close): past the cap there is no way to find the next command
/// boundary. Shared by both drivers.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Which connection driver a listener runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetDriver {
    /// Blocking thread-per-connection (default).
    Threads,
    /// Readiness-driven event loop (epoll on Linux, `poll(2)` on other
    /// unix). Falls back to [`NetDriver::Threads`] with a warning on
    /// platforms without a poller.
    Epoll,
}

impl NetDriver {
    pub fn parse(s: &str) -> Result<NetDriver, String> {
        match s {
            "threads" => Ok(NetDriver::Threads),
            "epoll" => Ok(NetDriver::Epoll),
            other => Err(format!("net.driver must be \"threads\" or \"epoll\", got {other:?}")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            NetDriver::Threads => "threads",
            NetDriver::Epoll => "epoll",
        }
    }
}

impl std::fmt::Display for NetDriver {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// `[net]` section of the experiment config.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetConfig {
    pub driver: NetDriver,
    /// Worker threads executing request handlers under the reactor driver
    /// (the reactor thread itself never runs model code).
    pub handlers: usize,
    /// Close a connection with no traffic for this long (reactor only; the
    /// blocking driver keeps idle connections parked in their reads).
    pub idle_timeout_ms: u64,
    /// Deadline for completing a started request frame/line (reactor only).
    pub read_timeout_ms: u64,
    /// Deadline for flushing a pending response (reactor only).
    pub write_timeout_ms: u64,
    /// Graceful-shutdown drain: in-flight requests get this long to finish
    /// before connections are force-closed.
    pub drain_ms: u64,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            driver: NetDriver::Threads,
            handlers: 4,
            idle_timeout_ms: 60_000,
            read_timeout_ms: 10_000,
            write_timeout_ms: 10_000,
            drain_ms: 2_000,
        }
    }
}

impl NetConfig {
    /// Read `[net]` overrides from a parsed TOML doc — shared by the
    /// experiment config and the cluster router config, so a single
    /// `[net]` section configures whichever listener the process runs. An
    /// unknown driver name warns and keeps the default rather than failing
    /// the whole config.
    pub fn from_doc(doc: &crate::config::TomlDoc) -> NetConfig {
        let d = NetConfig::default();
        let driver = match NetDriver::parse(&doc.str_or("net.driver", d.driver.as_str())) {
            Ok(v) => v,
            Err(e) => {
                crate::warn!("{e}; using \"{}\"", d.driver);
                d.driver
            }
        };
        NetConfig {
            driver,
            handlers: doc.usize_or("net.handlers", d.handlers).max(1),
            idle_timeout_ms: doc.usize_or("net.idle_timeout_ms", d.idle_timeout_ms as usize)
                as u64,
            read_timeout_ms: doc.usize_or("net.read_timeout_ms", d.read_timeout_ms as usize)
                as u64,
            write_timeout_ms: doc.usize_or("net.write_timeout_ms", d.write_timeout_ms as usize)
                as u64,
            drain_ms: doc.usize_or("net.drain_ms", d.drain_ms as usize) as u64,
        }
    }
}

/// What a [`Service`] wants done after dispatching one text line.
#[derive(Debug, PartialEq, Eq)]
pub enum TextAction {
    /// Send these bytes (possibly empty) and keep the connection.
    Reply(String),
    /// Close the connection without replying (the QUIT command).
    Quit,
}

/// The protocol brain behind a listener. One impl per server flavor; both
/// network drivers dispatch into the same impl, which is what guarantees
/// byte-identical responses across drivers.
pub trait Service: Send + Sync + 'static {
    /// The `dim` word of the binary server hello, or `None` to refuse
    /// binary connections entirely (the router does this while it cannot
    /// reach any replica to learn the embedding width).
    fn hello_dim(&self) -> Option<u32>;

    /// Answer one text line (newline included when one was on the wire —
    /// an EOF-truncated tail arrives without it, like `read_line` yields).
    fn text(&self, line: &str) -> TextAction;

    /// Answer one decoded binary frame by appending the response frame to
    /// `out`; returns `true` when the connection must close after `out`
    /// flushes (QUIT, hostile header).
    fn binary(&self, req: BinRequest, out: &mut Vec<u8>) -> bool;

    /// A transient accept(2) failure was survived (counted into STATS).
    fn note_accept_error(&self);

    /// The metrics registry the driver should record transport-level
    /// timings into (parse/flush stages, reactor loop iterations, writev
    /// batch sizes). `None` (the default) disables driver instrumentation.
    fn obs(&self) -> Option<Arc<crate::obs::Obs>> {
        None
    }
}

/// Shared shutdown/drain state for one listener: the stop flag, the count
/// of requests currently executing, and every live connection (so shutdown
/// can unblock parked reader threads by closing their sockets).
pub struct Lifecycle {
    stop: AtomicBool,
    busy: AtomicUsize,
    next_id: AtomicUsize,
    conns: Mutex<Vec<(usize, TcpStream)>>,
}

impl Lifecycle {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Arc<Lifecycle> {
        Arc::new(Lifecycle {
            stop: AtomicBool::new(false),
            busy: AtomicUsize::new(0),
            next_id: AtomicUsize::new(0),
            conns: Mutex::new(Vec::new()),
        })
    }

    /// Flip the stop flag; the driver observes it, stops accepting, drains,
    /// and returns from `serve`.
    pub fn begin_shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Requests currently executing (not merely connections held open).
    /// The drain phase waits on this, not on idle connections — an idle
    /// pooled client must not stall shutdown.
    pub fn busy(&self) -> usize {
        self.busy.load(Ordering::SeqCst)
    }

    pub(crate) fn begin_request(&self) {
        self.busy.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn end_request(&self) {
        self.busy.fetch_sub(1, Ordering::SeqCst);
    }

    /// Register a live connection for shutdown teardown. Returns a token
    /// for [`untrack`](Self::untrack); `None` if the clone failed (the
    /// connection still serves, it just cannot be force-closed early).
    pub(crate) fn track(&self, stream: &TcpStream) -> Option<usize> {
        let clone = stream.try_clone().ok()?;
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.conns.lock().expect("lifecycle lock poisoned").push((id, clone));
        Some(id)
    }

    pub(crate) fn untrack(&self, id: usize) {
        self.conns.lock().expect("lifecycle lock poisoned").retain(|(cid, _)| *cid != id);
    }

    /// Force-close every tracked connection (both directions), unblocking
    /// any handler thread parked in a read on it.
    pub(crate) fn close_all(&self) {
        let conns = self.conns.lock().expect("lifecycle lock poisoned");
        for (_, stream) in conns.iter() {
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Run `listener` on the configured driver until `lifecycle` begins
/// shutdown, then drain and return. The listener must already be in
/// nonblocking mode for both drivers (the accept loop polls the stop flag).
pub fn serve(
    listener: TcpListener,
    svc: Arc<dyn Service>,
    cfg: &NetConfig,
    lifecycle: Arc<Lifecycle>,
) {
    match cfg.driver {
        NetDriver::Threads => threads::serve(listener, svc, cfg, lifecycle),
        NetDriver::Epoll => {
            #[cfg(unix)]
            reactor::serve(listener, svc, cfg, lifecycle);
            #[cfg(not(unix))]
            {
                crate::warn!("net.driver = \"epoll\" unsupported on this platform; using threads");
                threads::serve(listener, svc, cfg, lifecycle);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn driver_parses_and_round_trips() {
        assert_eq!(NetDriver::parse("threads").unwrap(), NetDriver::Threads);
        assert_eq!(NetDriver::parse("epoll").unwrap(), NetDriver::Epoll);
        assert!(NetDriver::parse("tokio").is_err());
        assert_eq!(NetDriver::parse(NetDriver::Epoll.as_str()).unwrap(), NetDriver::Epoll);
        assert_eq!(format!("{}", NetDriver::Threads), "threads");
    }

    #[test]
    fn lifecycle_tracks_busy_and_stop() {
        let lc = Lifecycle::new();
        assert!(!lc.stopping());
        assert_eq!(lc.busy(), 0);
        lc.begin_request();
        lc.begin_request();
        assert_eq!(lc.busy(), 2);
        lc.end_request();
        assert_eq!(lc.busy(), 1);
        lc.begin_shutdown();
        assert!(lc.stopping());
    }

    #[test]
    fn lifecycle_untrack_removes_the_right_conn() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let b = TcpStream::connect(addr).unwrap();
        let lc = Lifecycle::new();
        let ta = lc.track(&a).unwrap();
        let _tb = lc.track(&b).unwrap();
        assert_eq!(lc.conns.lock().unwrap().len(), 2);
        lc.untrack(ta);
        assert_eq!(lc.conns.lock().unwrap().len(), 1);
        lc.close_all();
    }
}
