//! Closed-form parameter accounting for Tables 1–3 of the paper.
//!
//! Every `#Params` and `Space Saving Rate` cell is recomputed from the
//! embedding hyper-parameters and checked against the published number. This
//! is exact arithmetic, independent of training, so it is the one part of the
//! evaluation we reproduce digit-for-digit (one cell in Table 1 is internally
//! inconsistent in the paper; see [`PAPER_TABLE1`] notes and DESIGN.md §5).

use crate::util::{ceil_root, fmt_count, Table};

/// Vocabulary sizes implied by the paper's Regular-row parameter counts.
pub const GIGAWORD_VOCAB: usize = 30_428; // 7,789,568 / 256
pub const IWSLT_VOCAB: usize = 32_011; // 8,194,816 / 256
pub const SQUAD_VOCAB: usize = 118_655; // stated in §4
pub const SQUAD_DIM: usize = 300;

/// word2ket parameter count: `d · r · n · q` with `q = ⌈p^{1/n}⌉` (eq. 3).
pub fn w2k_params(vocab: usize, dim: usize, order: usize, rank: usize) -> usize {
    let q = ceil_root(dim, order as u32);
    vocab * rank * order * q
}

/// word2ketXS parameter count: `r · n · q · t` with `q = ⌈p^{1/n}⌉`,
/// `t = ⌈d^{1/n}⌉` (eq. 4).
pub fn xs_params(vocab: usize, dim: usize, order: usize, rank: usize) -> usize {
    let q = ceil_root(dim, order as u32);
    let t = ceil_root(vocab, order as u32);
    rank * order * q * t
}

/// Regular embedding: `d · p`.
pub fn regular_params(vocab: usize, dim: usize) -> usize {
    vocab * dim
}

/// One row of a paper table.
#[derive(Debug, Clone)]
pub struct PaperRow {
    pub label: &'static str,
    /// "order/rank" as printed in the paper.
    pub order_rank: &'static str,
    pub dim: usize,
    /// Parameter count we compute from the formulas above.
    pub computed: usize,
    /// Parameter count printed in the paper.
    pub published: usize,
    /// The regular row this row's saving rate is measured against.
    pub baseline_params: usize,
    /// Saving rate printed in the paper (rounded as printed).
    pub published_rate: f64,
    pub note: &'static str,
}

impl PaperRow {
    pub fn computed_rate(&self) -> f64 {
        self.baseline_params as f64 / self.computed as f64
    }

    pub fn matches(&self) -> bool {
        self.computed == self.published
    }
}

/// Table 1 — GIGAWORD summarization embeddings.
pub fn paper_table1() -> Vec<PaperRow> {
    let d = GIGAWORD_VOCAB;
    let reg256 = regular_params(d, 256);
    let reg8000 = regular_params(d, 8000);
    vec![
        PaperRow {
            label: "Regular",
            order_rank: "1/1",
            dim: 256,
            computed: reg256,
            published: 7_789_568,
            baseline_params: reg256,
            published_rate: 1.0,
            note: "",
        },
        PaperRow {
            label: "word2ket",
            order_rank: "4/1",
            dim: 256,
            computed: w2k_params(d, 256, 4, 1),
            published: 486_848,
            baseline_params: reg256,
            published_rate: 16.0,
            note: "",
        },
        PaperRow {
            label: "word2ketXS",
            order_rank: "2/10",
            dim: 400,
            computed: xs_params(d, 400, 2, 10),
            published: 70_000,
            baseline_params: reg256,
            published_rate: 111.0,
            note: "",
        },
        PaperRow {
            label: "word2ketXS",
            order_rank: "4/1",
            dim: 256,
            computed: xs_params(d, 256, 4, 1),
            published: 224,
            baseline_params: reg256,
            published_rate: 34_775.0,
            note: "",
        },
        PaperRow {
            label: "Regular",
            order_rank: "1/1",
            dim: 8000,
            computed: reg8000,
            published: 243_424_000,
            baseline_params: reg8000,
            published_rate: 1.0,
            note: "",
        },
        PaperRow {
            label: "word2ketXS",
            order_rank: "2/10",
            dim: 8000,
            computed: xs_params(d, 8000, 2, 10),
            published: 19_200,
            baseline_params: reg8000,
            published_rate: 12_678.0,
            note: "paper cell inconsistent with eq. 4 (q=⌈√8000⌉=90, t=175 ⇒ 315,000); \
                   19,200 requires q·t=960, impossible with q²≥8000 and t²≥30,428",
        },
    ]
}

/// Table 2 — IWSLT2014 DE-EN translation embeddings.
pub fn paper_table2() -> Vec<PaperRow> {
    let d = IWSLT_VOCAB;
    let reg = regular_params(d, 256);
    vec![
        PaperRow {
            label: "Regular",
            order_rank: "1/1",
            dim: 256,
            computed: reg,
            published: 8_194_816,
            baseline_params: reg,
            published_rate: 1.0,
            note: "",
        },
        PaperRow {
            label: "word2ketXS",
            order_rank: "2/30",
            dim: 400,
            computed: xs_params(d, 400, 2, 30),
            published: 214_800,
            baseline_params: reg,
            published_rate: 38.0,
            note: "",
        },
        PaperRow {
            label: "word2ketXS",
            order_rank: "2/10",
            dim: 400,
            computed: xs_params(d, 400, 2, 10),
            published: 71_600,
            baseline_params: reg,
            published_rate: 114.0,
            note: "",
        },
        PaperRow {
            label: "word2ketXS",
            order_rank: "3/10",
            dim: 1000,
            computed: xs_params(d, 1000, 3, 10),
            published: 9_600,
            baseline_params: reg,
            published_rate: 853.0,
            note: "",
        },
    ]
}

/// Table 3 — SQuAD / DrQA embeddings.
pub fn paper_table3() -> Vec<PaperRow> {
    let d = SQUAD_VOCAB;
    let reg = regular_params(d, SQUAD_DIM);
    vec![
        PaperRow {
            label: "Regular",
            order_rank: "1/1",
            dim: SQUAD_DIM,
            computed: reg,
            published: 35_596_500,
            baseline_params: reg,
            published_rate: 1.0,
            note: "",
        },
        PaperRow {
            label: "word2ketXS",
            order_rank: "2/2",
            dim: SQUAD_DIM,
            computed: xs_params(d, SQUAD_DIM, 2, 2),
            published: 24_840,
            baseline_params: reg,
            published_rate: 1_433.0,
            note: "",
        },
        PaperRow {
            label: "word2ketXS",
            order_rank: "4/1",
            dim: SQUAD_DIM,
            computed: xs_params(d, SQUAD_DIM, 4, 1),
            published: 380,
            baseline_params: reg,
            published_rate: 93_675.0,
            note: "four 19×5 matrices (Fig. 3 caption)",
        },
    ]
}

fn render_one(title: &str, rows: &[PaperRow]) -> String {
    let mut t = Table::new(vec![
        "Embedding",
        "Order/Rank",
        "Dim",
        "#Params (ours)",
        "#Params (paper)",
        "Rate (ours)",
        "Rate (paper)",
        "Match",
    ])
    .with_title(title.to_string());
    for r in rows {
        t.add_row(vec![
            r.label.to_string(),
            r.order_rank.to_string(),
            r.dim.to_string(),
            fmt_count(r.computed as u64),
            fmt_count(r.published as u64),
            fmt_count(r.computed_rate().round() as u64),
            fmt_count(r.published_rate.round() as u64),
            if r.matches() { "✓".to_string() } else { "✗ (see note)".to_string() },
        ]);
    }
    let mut s = t.render();
    for r in rows {
        if !r.note.is_empty() {
            s.push_str(&format!("\n  note [{} {}]: {}", r.label, r.order_rank, r.note));
        }
    }
    s.push('\n');
    s
}

/// Render all three tables with paper-vs-computed columns (the `w2k params`
/// subcommand and the `space_saving` bench).
pub fn render_paper_tables() -> String {
    let mut s = String::new();
    s.push_str(&render_one(
        "Table 1 — GIGAWORD embedding parameter accounting",
        &paper_table1(),
    ));
    s.push('\n');
    s.push_str(&render_one("Table 2 — IWSLT2014 DE-EN", &paper_table2()));
    s.push('\n');
    s.push_str(&render_one("Table 3 — SQuAD / DrQA", &paper_table3()));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_cells_match_paper() {
        let rows = paper_table1();
        // All rows except the documented-inconsistent 8000-dim XS row.
        assert_eq!(rows[0].computed, 7_789_568);
        assert_eq!(rows[1].computed, 486_848);
        assert_eq!(rows[2].computed, 70_000);
        assert_eq!(rows[3].computed, 224);
        assert_eq!(rows[4].computed, 243_424_000);
        assert!(rows[0].matches() && rows[1].matches() && rows[2].matches());
        assert!(rows[3].matches() && rows[4].matches());
        assert!(!rows[5].matches(), "paper's 19,200 cell is inconsistent with eq. 4");
        assert_eq!(rows[5].computed, 315_000);
    }

    #[test]
    fn table1_rates_match_paper() {
        let rows = paper_table1();
        assert!((rows[1].computed_rate() - 16.0).abs() < 0.01);
        assert!((rows[2].computed_rate() - 111.3).abs() < 0.1);
        assert!((rows[3].computed_rate() - 34_775.0).abs() < 1.0);
    }

    #[test]
    fn table2_cells_match_paper() {
        let rows = paper_table2();
        for r in &rows {
            assert!(r.matches(), "{} {}: computed {} != published {}", r.label, r.order_rank, r.computed, r.published);
        }
        assert!((rows[1].computed_rate() - 38.1).abs() < 0.1);
        assert!((rows[2].computed_rate() - 114.5).abs() < 0.1);
        assert!((rows[3].computed_rate() - 853.6).abs() < 0.1);
    }

    #[test]
    fn table3_cells_match_paper() {
        let rows = paper_table3();
        for r in &rows {
            assert!(r.matches(), "{} {}: computed {} != published {}", r.label, r.order_rank, r.computed, r.published);
        }
        assert!((rows[1].computed_rate() - 1_432.9).abs() < 0.5);
        assert!((rows[2].computed_rate() - 93_675.0).abs() < 1.0);
    }

    #[test]
    fn render_includes_checkmarks() {
        let s = render_paper_tables();
        assert!(s.contains("Table 1"));
        assert!(s.contains("Table 3"));
        assert!(s.contains('✓'));
        assert!(s.contains("34,775"));
        assert!(s.contains("93,675"));
    }
}
