//! word2ketXS (paper §3.2, eq. 4): the whole `p × d` embedding operator as
//! `F = Σ_{k=1..r} ⊗_{j=1..n} F_jk` with `F_jk ∈ R^{q×t}`, `q = ⌈p^{1/n}⌉`,
//! `t = ⌈d^{1/n}⌉`. Storage `r·n·q·t` for the *entire* matrix.
//!
//! Row access is lazy: for word `i` with mixed-radix digits `(i_1..i_n)` over
//! base `t`, row `i` of `Fᵀ` is `Σ_k ⊗_j (F_jk column i_j)` — only one
//! column of each factor is touched (§3.2's lazy-tensor identity). This is
//! the serving-path hot primitive benchmarked in `lookup_throughput`.

use super::EmbeddingStore;
use crate::kron::{kron_accumulate, KronScratch, MixedRadix};
use crate::repr::{kernels, FactorGeometry, FactoredRepr, Repr};
use crate::util::{ceil_root, Rng};

/// Factored embedding operator.
///
/// We store each factor transposed, as a `t × q` row-major matrix
/// (`factors[k][j]` row `c` = column `c` of the paper's `F_jk`), so lazy row
/// reconstruction reads contiguous memory.
#[derive(Debug, Clone)]
pub struct Word2KetXS {
    vocab: usize,
    dim: usize,
    order: usize,
    rank: usize,
    /// q: per-factor output dim (embedding side).
    leaf_q: usize,
    /// t: per-factor input dim (vocabulary side).
    leaf_t: usize,
    /// factors[k * order + j] is a t×q row-major matrix (transposed F_jk).
    factors: Vec<Vec<f32>>,
    radix: MixedRadix,
}

impl Word2KetXS {
    pub fn random(vocab: usize, dim: usize, order: usize, rank: usize, rng: &mut Rng) -> Self {
        assert!(order >= 2, "word2ketXS needs order >= 2");
        // The lazy reconstruction / factored-inner fast paths use fixed
        // 8-slot digit buffers; enforce the bound here (always, not just in
        // debug) so release builds cannot silently mis-slice. Config
        // validation rejects order > 8 with a friendlier message.
        assert!(order <= 8, "word2ketXS supports order <= 8");
        let q = ceil_root(dim, order as u32).max(2);
        let t = ceil_root(vocab, order as u32).max(2);
        // Scale so each reconstructed entry (product of n entries, summed over
        // r) has st.dev. comparable to a Glorot-initialized regular embedding.
        let target = (3.0 / dim as f32).sqrt();
        let a = (target / (rank as f32).sqrt()).powf(1.0 / order as f32);
        let factors = (0..rank * order)
            .map(|i| {
                let mut child = rng.fork(i as u64);
                child.uniform_vec(t * q, -a, a)
            })
            .collect();
        Word2KetXS {
            vocab,
            dim,
            order,
            rank,
            leaf_q: q,
            leaf_t: t,
            factors,
            radix: MixedRadix::uniform(t, order),
        }
    }

    /// Rebuild from explicit factor matrices (snapshot loading / fitted
    /// stores): `factors[k·n + j]` is the `t × q` row-major transposed
    /// `F_jk`. Validates geometry instead of asserting, so a corrupt
    /// snapshot yields a typed error rather than a panic.
    pub fn from_factors(
        vocab: usize,
        dim: usize,
        order: usize,
        rank: usize,
        leaf_q: usize,
        leaf_t: usize,
        factors: Vec<Vec<f32>>,
    ) -> crate::Result<Word2KetXS> {
        if !(2..=8).contains(&order) || rank == 0 || leaf_q == 0 || leaf_t == 0 {
            return Err(crate::Error::Snapshot(format!(
                "bad word2ketXS geometry: order={order} rank={rank} q={leaf_q} t={leaf_t}"
            )));
        }
        let full = leaf_q
            .checked_pow(order as u32)
            .ok_or_else(|| crate::Error::Snapshot("word2ketXS q^order overflows".into()))?;
        let cap = leaf_t
            .checked_pow(order as u32)
            .ok_or_else(|| crate::Error::Snapshot("word2ketXS t^order overflows".into()))?;
        // Cover dim/vocab, and stay within the minimal-root bound (see
        // `Word2Ket::from_leaves`): hostile oversized q/t would otherwise
        // blow up per-lookup scratch buffers.
        if full < dim
            || cap < vocab
            || full > dim.saturating_mul(1usize << order)
            || cap > vocab.saturating_mul(1usize << order)
        {
            return Err(crate::Error::Snapshot(format!(
                "word2ketXS geometry inconsistent with {vocab}x{dim} (q^n={full}, t^n={cap})"
            )));
        }
        let per = leaf_t
            .checked_mul(leaf_q)
            .ok_or_else(|| crate::Error::Snapshot("word2ketXS geometry overflows".into()))?;
        let n_factors = rank
            .checked_mul(order)
            .ok_or_else(|| crate::Error::Snapshot("word2ketXS geometry overflows".into()))?;
        if factors.len() != n_factors || factors.iter().any(|f| f.len() != per) {
            return Err(crate::Error::Snapshot(format!(
                "word2ketXS expects {rank}x{order} factors of {per} values"
            )));
        }
        Ok(Word2KetXS {
            vocab,
            dim,
            order,
            rank,
            leaf_q,
            leaf_t,
            factors,
            radix: MixedRadix::uniform(leaf_t, order),
        })
    }

    pub fn order(&self) -> usize {
        self.order
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn leaf_q(&self) -> usize {
        self.leaf_q
    }

    /// All factor matrices in `k·n + j` order (snapshot serialization).
    pub fn factors(&self) -> &[Vec<f32>] {
        &self.factors
    }

    pub fn leaf_t(&self) -> usize {
        self.leaf_t
    }

    /// Column `c` of factor `F_jk` — contiguous because we store transposed.
    #[inline]
    pub fn factor_col(&self, k: usize, j: usize, c: usize) -> &[f32] {
        let f = &self.factors[k * self.order + j];
        &f[c * self.leaf_q..(c + 1) * self.leaf_q]
    }

    /// Mutable access for training/loading trained factors.
    pub fn factor_col_mut(&mut self, k: usize, j: usize, c: usize) -> &mut [f32] {
        let q = self.leaf_q;
        let f = &mut self.factors[k * self.order + j];
        &mut f[c * q..(c + 1) * q]
    }

    /// True when `q^n == p` exactly: reconstruction is not truncated and the
    /// factored inner product below equals the dense dot product of rows.
    pub fn exact_dim(&self) -> bool {
        self.leaf_q.checked_pow(self.order as u32) == Some(self.dim)
    }

    /// Factored inner product between rows `a` and `b` without materializing
    /// either (§2.3 generalized to the shared-factor form of §3.2):
    ///
    /// `⟨row a, row b⟩ = Σ_{k,k'} Π_j ⟨F_jk[:, a_j], F_jk'[:, b_j]⟩`
    ///
    /// `O(r² n q)` time, `O(1)` space. Equals the dense dot product when
    /// [`exact_dim`](Self::exact_dim) holds (the inner product runs over the
    /// full `q^n` tensor, which `lookup` truncates to `p` otherwise).
    pub fn inner(&self, a: usize, b: usize) -> f32 {
        debug_assert!(self.order <= 8, "order > 8 unsupported on the fast path");
        let mut da = [0usize; 8];
        let mut db = [0usize; 8];
        self.radix.decode_into(a, &mut da[..self.order]);
        self.radix.decode_into(b, &mut db[..self.order]);
        kernels::factored_digit_inner(self.rank, self.order, &da, &db, |k, j, c| {
            self.factor_col(k, j, c)
        })
    }

    /// Reconstruct row `id` into a caller buffer of length `dim` using
    /// caller-owned scratch (the trait-level
    /// [`EmbeddingStore::lookup_into`] wraps this with per-thread scratch;
    /// batch paths pass their own to stay re-entrant).
    fn reconstruct_into(
        &self,
        id: usize,
        out: &mut [f32],
        digits: &mut [usize],
        scratch: &mut KronScratch,
    ) {
        debug_assert_eq!(out.len(), self.dim);
        debug_assert_eq!(digits.len(), self.order);
        self.radix.decode_into(id, digits);
        out.fill(0.0);
        if self.order == 2 {
            // Fused rank-accumulated outer product: the dominant case
            // (paper Tables 1–3 all include order-2 rows). `dim` may be
            // shorter than q² (truncated reconstruction) — the shared
            // kernel truncates to `out`.
            for k in 0..self.rank {
                let a = self.factor_col(k, 0, digits[0]);
                let b = self.factor_col(k, 1, digits[1]);
                kernels::kron2_accumulate(a, b, out);
            }
            return;
        }
        let mut cols: [&[f32]; 8] = [&[]; 8];
        debug_assert!(self.order <= 8, "order > 8 unsupported on the fast path");
        for k in 0..self.rank {
            for (j, c) in cols.iter_mut().take(self.order).enumerate() {
                *c = self.factor_col(k, j, digits[j]);
            }
            kron_accumulate(&cols[..self.order], out, scratch);
        }
    }
}

impl EmbeddingStore for Word2KetXS {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn num_params(&self) -> usize {
        // r · n · q · t
        self.rank * self.order * self.leaf_q * self.leaf_t
    }

    fn lookup(&self, id: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.lookup_into(id, &mut out);
        out
    }

    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        // The serving hot path: per-thread digit/kron scratch makes this
        // allocation-free in steady state (§Perf in EXPERIMENTS.md).
        kernels::with_lookup_scratch(|s| {
            self.reconstruct_into(id, out, &mut s.digits[..self.order], &mut s.kron)
        });
    }

    fn lookup_batch_into(&self, ids: &[usize], out: &mut Vec<f32>) {
        // Scratch-reusing override of the trait default: same dedup-and-
        // scatter, but the per-thread scratch is borrowed once for the
        // whole batch instead of once per row — a steady-state drain
        // allocates nothing here.
        kernels::with_lookup_scratch(|s| {
            let digits = &mut s.digits[..self.order];
            let kron = &mut s.kron;
            super::dedup_scatter_into(ids, self.dim, out, |id, row| {
                self.reconstruct_into(id, row, digits, kron)
            });
        });
    }

    fn repr(&self) -> Repr<'_> {
        Repr::Word2KetXS(self)
    }

    fn describe(&self) -> String {
        format!(
            "word2ketXS order={} rank={} q={} t={} ({}×{}, {} params, {:.0}× saving)",
            self.order,
            self.rank,
            self.leaf_q,
            self.leaf_t,
            self.vocab,
            self.dim,
            self.num_params(),
            self.space_saving_rate()
        )
    }
}

/// Factored-space contract (see [`crate::repr`]). Handed out by
/// [`Repr::factored`] only when `q^n == p` (untruncated), where the shared
/// factored inner product equals the dense dot product of rows.
impl FactoredRepr for Word2KetXS {
    fn geometry(&self) -> FactorGeometry {
        FactorGeometry { order: self.order, rank: self.rank, leaf_dim: self.leaf_q }
    }

    fn factors<'s>(&'s self, id: usize, k: usize, out: &mut [&'s [f32]]) {
        debug_assert_eq!(out.len(), self.order);
        let mut digits = [0usize; 8];
        self.radix.decode_into(id, &mut digits[..self.order]);
        for (j, col) in out.iter_mut().enumerate() {
            *col = self.factor_col(k, j, digits[j]);
        }
    }

    fn kind_name(&self) -> &'static str {
        "word2ketXS"
    }

    fn inner(&self, a: usize, b: usize) -> f32 {
        Word2KetXS::inner(self, a, b)
    }

    fn block_inner(&self, a: usize, bs: &[usize], out: &mut [f32]) {
        // Shared digit-hoisted block kernel: the query word decodes once
        // for the whole block; per-pair arithmetic is identical to `inner`.
        kernels::factored_digit_block(
            self.rank,
            self.order,
            |i, d: &mut [usize; 8]| self.radix.decode_into(i, &mut d[..self.order]),
            |k, j, c| self.factor_col(k, j, c),
            a,
            bs,
            out,
        );
    }

    fn write_row(&self, id: usize, out: &mut [f32]) {
        EmbeddingStore::lookup_into(self, id, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::materialize;
    use crate::kron::kron_mat;
    use crate::tensor::Tensor;

    #[test]
    fn paper_fig3_setting_380_params() {
        // Fig. 3: 118,655 × 300 as four 19×5 matrices (order 4, rank 1) = 380.
        let mut rng = Rng::new(0);
        let e = Word2KetXS::random(118_655, 300, 4, 1, &mut rng);
        assert_eq!(e.leaf_q(), 5);
        assert_eq!(e.leaf_t(), 19);
        assert_eq!(e.num_params(), 380);
        // Space saving ≈ 93,675 (paper Table 3).
        let rate = e.space_saving_rate();
        assert!((rate - 93_675.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn paper_table3_xs22() {
        // Table 3: XS 2/2 → 24,840 params, saving 1,433.
        let mut rng = Rng::new(1);
        let e = Word2KetXS::random(118_655, 300, 2, 2, &mut rng);
        assert_eq!(e.num_params(), 24_840);
        assert!((e.space_saving_rate() - 1_432.9).abs() < 1.0);
    }

    #[test]
    fn lazy_row_matches_dense_kron() {
        // Build a small XS store, materialize the dense operator by explicit
        // Kronecker products of the factors, and compare every row.
        let mut rng = Rng::new(2);
        let vocab = 9; // t = 3 for order 2
        let dim = 4; // q = 2
        let e = Word2KetXS::random(vocab, dim, 2, 2, &mut rng);
        assert_eq!(e.leaf_t(), 3);
        assert_eq!(e.leaf_q(), 2);

        // Dense reconstruction: F = Σ_k F_1k ⊗ F_2k (q^n × t^n), embeddings
        // are columns of F, i.e. rows of Fᵀ.
        let mut dense = Tensor::zeros(vec![4, 9]);
        for k in 0..2 {
            // Rebuild paper-layout (q×t) factors from our transposed storage.
            let mut f1 = Tensor::zeros(vec![2, 3]);
            let mut f2 = Tensor::zeros(vec![2, 3]);
            for c in 0..3 {
                for r in 0..2 {
                    f1.set2(r, c, e.factor_col(k, 0, c)[r]);
                    f2.set2(r, c, e.factor_col(k, 1, c)[r]);
                }
            }
            dense = dense.add(&kron_mat(&f1, &f2)).unwrap();
        }
        for word in 0..vocab {
            let lazy = e.lookup(word);
            for d in 0..dim {
                assert!(
                    (lazy[d] - dense.at2(d, word)).abs() < 1e-5,
                    "word {word} dim {d}: {} vs {}",
                    lazy[d],
                    dense.at2(d, word)
                );
            }
        }
    }

    #[test]
    fn factored_inner_matches_dense_lookup() {
        // Shared-factor inner product vs dot of materialized rows. Dims are
        // exact powers (q^n == p) so truncation cannot interfere; the
        // acceptance tolerance is 1e-5 relative.
        let mut rng = Rng::new(6);
        for (vocab, dim, order, rank) in [(50usize, 16usize, 2usize, 2usize), (40, 27, 3, 3)] {
            let e = Word2KetXS::random(vocab, dim, order, rank, &mut rng);
            assert!(e.exact_dim(), "test requires q^n == p");
            for (a, b) in [(0usize, 1usize), (7, 7), (3, vocab - 1), (vocab - 1, 0)] {
                let va = e.lookup(a);
                let vb = e.lookup(b);
                let dense: f32 = va.iter().zip(vb.iter()).map(|(x, y)| x * y).sum();
                let fast = e.inner(a, b);
                assert!(
                    (dense - fast).abs() < 1e-5 * dense.abs().max(1.0),
                    "({a},{b}) o{order}r{rank}: dense {dense} vs factored {fast}"
                );
            }
        }
    }

    #[test]
    fn truncated_dims_are_flagged_inexact() {
        let mut rng = Rng::new(7);
        // dim 300, order 2 → q = 18, 18² = 324 > 300: truncated.
        let e = Word2KetXS::random(100, 300, 2, 1, &mut rng);
        assert!(!e.exact_dim());
    }

    #[test]
    fn batch_consistency_and_determinism() {
        let mut rng = Rng::new(3);
        let e = Word2KetXS::random(100, 16, 2, 3, &mut rng);
        let m = materialize(&e);
        for id in [0usize, 7, 55, 99] {
            assert_eq!(m.row(id), e.lookup(id).as_slice());
        }
    }

    #[test]
    fn padding_vocab_capacity_exceeds_d() {
        // t^n >= d strictly here: 118,655 < 19^4 = 130,321; extra capacity is
        // simply never indexed.
        let mut rng = Rng::new(4);
        let e = Word2KetXS::random(10, 8, 3, 1, &mut rng); // t=3 ⇒ capacity 27
        assert_eq!(e.leaf_t(), 3);
        let v = e.lookup(9); // last real word
        assert_eq!(v.len(), 8);
    }

    #[test]
    fn init_scale_reasonable() {
        // Reconstructed entries should be same order of magnitude as a Glorot
        // regular embedding (±sqrt(3/p)), not exploding with rank/order.
        let mut rng = Rng::new(5);
        let e = Word2KetXS::random(1000, 64, 2, 10, &mut rng);
        let m = materialize(&e);
        let rms = (m.data().iter().map(|x| x * x).sum::<f32>() / m.len() as f32).sqrt();
        let glorot = (3.0f32 / 64.0).sqrt() / 3.0f32.sqrt(); // uniform std = a/sqrt(3)
        assert!(
            rms > glorot * 0.1 && rms < glorot * 10.0,
            "rms {rms} vs glorot std {glorot}"
        );
    }
}
