//! Parameter-sharing baseline via the hashing trick (related work §4.1,
//! Suzuki & Nagata 2016 style): each (word, dimension) coordinate maps to one
//! of `B` shared weights through a hash, with a per-coordinate sign hash to
//! decorrelate collisions.

use super::EmbeddingStore;
use crate::util::rng::splitmix64;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct HashedEmbedding {
    vocab: usize,
    dim: usize,
    buckets: usize,
    weights: Vec<f32>,
    seed: u64,
}

impl HashedEmbedding {
    pub fn random(vocab: usize, dim: usize, buckets: usize, rng: &mut Rng) -> Self {
        assert!(buckets >= 1);
        let a = (3.0 / dim as f32).sqrt();
        HashedEmbedding {
            vocab,
            dim,
            buckets,
            weights: rng.uniform_vec(buckets, -a, a),
            seed: rng.next_u64(),
        }
    }

    #[inline]
    fn coord_hash(&self, id: usize, j: usize) -> (usize, f32) {
        let mut h = self
            .seed
            .wrapping_add((id as u64) << 32)
            .wrapping_add(j as u64);
        let x = splitmix64(&mut h);
        let bucket = (x % self.buckets as u64) as usize;
        let sign = if (x >> 63) == 0 { 1.0 } else { -1.0 };
        (bucket, sign)
    }

    /// Rebuild from serialized parts (snapshot loading): the bucket weights
    /// plus the hash seed that fixes the (word, dim) → bucket mapping.
    pub fn from_parts(
        vocab: usize,
        dim: usize,
        buckets: usize,
        seed: u64,
        weights: Vec<f32>,
    ) -> crate::Result<Self> {
        if buckets == 0 || weights.len() != buckets {
            return Err(crate::Error::Snapshot(format!(
                "hashed parts mismatch: {} weights for {buckets} buckets",
                weights.len()
            )));
        }
        Ok(HashedEmbedding { vocab, dim, buckets, weights, seed })
    }

    pub fn buckets(&self) -> usize {
        self.buckets
    }

    /// Shared bucket weights (snapshot serialization).
    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    /// The coordinate-hash seed; must travel with the weights or every
    /// lookup would land on different buckets.
    pub fn seed(&self) -> u64 {
        self.seed
    }
}

impl EmbeddingStore for HashedEmbedding {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn num_params(&self) -> usize {
        self.buckets
    }

    fn lookup(&self, id: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.lookup_into(id, &mut out);
        out
    }

    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        for (j, o) in out.iter_mut().enumerate() {
            let (b, s) = self.coord_hash(id, j);
            *o = s * self.weights[b];
        }
    }

    fn repr(&self) -> crate::repr::Repr<'_> {
        crate::repr::Repr::Hashed(self)
    }

    fn describe(&self) -> String {
        format!(
            "Hashed B={} ({}×{}, {:.1}× saving)",
            self.buckets,
            self.vocab,
            self.dim,
            self.space_saving_rate()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_lookup() {
        let mut rng = Rng::new(0);
        let e = HashedEmbedding::random(100, 16, 64, &mut rng);
        assert_eq!(e.lookup(42), e.lookup(42));
        assert_ne!(e.lookup(42), e.lookup(43));
    }

    #[test]
    fn params_equal_buckets() {
        let mut rng = Rng::new(1);
        let e = HashedEmbedding::random(1000, 50, 128, &mut rng);
        assert_eq!(e.num_params(), 128);
        let expected = 1000.0 * 50.0 / 128.0;
        assert!((e.space_saving_rate() - expected).abs() < 1e-9);
    }

    #[test]
    fn values_are_signed_bucket_weights() {
        let mut rng = Rng::new(2);
        let e = HashedEmbedding::random(10, 8, 4, &mut rng);
        let v = e.lookup(3);
        for x in v {
            assert!(
                e.weights.iter().any(|w| (w - x).abs() < 1e-7 || (w + x).abs() < 1e-7),
                "{x} not ±bucket weight"
            );
        }
    }
}
