//! Uniform quantization baseline (related work §4.1: Gupta et al. 2015,
//! May et al. 2019). Stores b-bit codes plus one (scale, offset) pair per
//! row. Space saving is bounded by 32/b for 32-bit floats — the paper's
//! argument for why bit-encoding methods cannot reach word2ketXS rates.

use super::EmbeddingStore;
use crate::util::Rng;

/// Per-row uniformly quantized embedding table.
#[derive(Debug, Clone)]
pub struct QuantizedEmbedding {
    vocab: usize,
    dim: usize,
    bits: usize,
    /// Packed codes, `bits` per entry, row-major.
    codes: Vec<u32>,
    /// Per-row dequantization: value = offset + code * scale.
    scales: Vec<f32>,
    offsets: Vec<f32>,
}

impl QuantizedEmbedding {
    /// Quantize an existing dense matrix row-by-row.
    pub fn from_dense(vocab: usize, dim: usize, data: &[f32], bits: usize) -> Self {
        assert!((1..=16).contains(&bits));
        assert_eq!(data.len(), vocab * dim);
        let levels = (1u32 << bits) - 1;
        let mut codes = vec![0u32; (vocab * dim * bits + 31) / 32];
        let mut scales = vec![0.0f32; vocab];
        let mut offsets = vec![0.0f32; vocab];
        for r in 0..vocab {
            let row = &data[r * dim..(r + 1) * dim];
            let lo = row.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let scale = if hi > lo { (hi - lo) / levels as f32 } else { 1.0 };
            scales[r] = scale;
            offsets[r] = lo;
            for (c, &x) in row.iter().enumerate() {
                let code = (((x - lo) / scale).round() as u32).min(levels);
                set_bits(&mut codes, (r * dim + c) * bits, bits, code);
            }
        }
        QuantizedEmbedding { vocab, dim, bits, codes, scales, offsets }
    }

    pub fn random(vocab: usize, dim: usize, bits: usize, rng: &mut Rng) -> Self {
        let a = (3.0 / dim as f32).sqrt();
        let dense = rng.uniform_vec(vocab * dim, -a, a);
        Self::from_dense(vocab, dim, &dense, bits)
    }

    /// Rebuild from serialized parts (snapshot loading). Validates shapes
    /// instead of asserting, so a corrupt snapshot yields a typed error.
    pub fn from_parts(
        vocab: usize,
        dim: usize,
        bits: usize,
        codes: Vec<u32>,
        scales: Vec<f32>,
        offsets: Vec<f32>,
    ) -> crate::Result<Self> {
        if !(1..=16).contains(&bits) {
            return Err(crate::Error::Snapshot(format!("quantized bits {bits} outside 1..=16")));
        }
        let want_codes = vocab
            .checked_mul(dim)
            .and_then(|x| x.checked_mul(bits))
            .ok_or_else(|| crate::Error::Snapshot("quantized geometry overflows".into()))?
            .div_ceil(32);
        if codes.len() != want_codes || scales.len() != vocab || offsets.len() != vocab {
            return Err(crate::Error::Snapshot(format!(
                "quantized parts mismatch: {} codes (want {want_codes}), {} scales, {} offsets \
                 for vocab {vocab}",
                codes.len(),
                scales.len(),
                offsets.len()
            )));
        }
        Ok(QuantizedEmbedding { vocab, dim, bits, codes, scales, offsets })
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Packed code words (snapshot serialization).
    pub fn codes(&self) -> &[u32] {
        &self.codes
    }

    /// Per-row dequantization scales.
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Per-row dequantization offsets.
    pub fn offsets(&self) -> &[f32] {
        &self.offsets
    }

    /// Worst-case reconstruction error bound: scale/2 per element.
    pub fn max_row_error(&self, id: usize) -> f32 {
        self.scales[id] / 2.0
    }
}

pub(crate) fn set_bits(words: &mut [u32], bit_off: usize, nbits: usize, val: u32) {
    let w = bit_off / 32;
    let o = bit_off % 32;
    words[w] |= val << o;
    if o + nbits > 32 {
        words[w + 1] |= val >> (32 - o);
    }
}

/// Extract `nbits` at `bit_off` from a packed code array; shared with the
/// snapshot store's mapped reconstruction so both decode identically.
pub(crate) fn get_bits(words: &[u32], bit_off: usize, nbits: usize) -> u32 {
    let w = bit_off / 32;
    let o = bit_off % 32;
    let mask = if nbits == 32 { u32::MAX } else { (1u32 << nbits) - 1 };
    let mut v = words[w] >> o;
    if o + nbits > 32 {
        v |= words[w + 1] << (32 - o);
    }
    v & mask
}

impl EmbeddingStore for QuantizedEmbedding {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn num_params(&self) -> usize {
        // Count in f32-equivalents, the paper's accounting unit: packed codes
        // occupy dim·bits/32 floats per row, plus scale+offset.
        let code_floats = (self.vocab * self.dim * self.bits + 31) / 32;
        code_floats + 2 * self.vocab
    }

    fn lookup(&self, id: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.lookup_into(id, &mut out);
        out
    }

    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let scale = self.scales[id];
        let off = self.offsets[id];
        for (c, o) in out.iter_mut().enumerate() {
            let code = get_bits(&self.codes, (id * self.dim + c) * self.bits, self.bits);
            *o = off + code as f32 * scale;
        }
    }

    fn repr(&self) -> crate::repr::Repr<'_> {
        crate::repr::Repr::Quantized(self)
    }

    fn describe(&self) -> String {
        format!(
            "Quantized {}-bit ({}×{}, {} f32-equiv params, {:.1}× saving)",
            self.bits,
            self.vocab,
            self.dim,
            self.num_params(),
            self.space_saving_rate()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_packing_roundtrip() {
        let mut words = vec![0u32; 4];
        let vals = [5u32, 7, 0, 255, 128, 3];
        for (i, &v) in vals.iter().enumerate() {
            set_bits(&mut words, i * 9, 9, v); // 9-bit crosses word boundaries
        }
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(get_bits(&words, i * 9, 9), v);
        }
    }

    #[test]
    fn reconstruction_error_bounded() {
        let mut rng = Rng::new(0);
        let a = (3.0f32 / 16.0).sqrt();
        let dense = rng.uniform_vec(10 * 16, -a, a);
        let q = QuantizedEmbedding::from_dense(10, 16, &dense, 8);
        for r in 0..10 {
            let rec = q.lookup(r);
            let bound = q.max_row_error(r) + 1e-6;
            for c in 0..16 {
                let err = (rec[c] - dense[r * 16 + c]).abs();
                assert!(err <= bound, "row {r} col {c}: err {err} > bound {bound}");
            }
        }
    }

    #[test]
    fn saving_rate_bounded_by_32_over_b() {
        // The paper's §4.1 point: bit encoding saves at most 32× (b=1).
        let mut rng = Rng::new(1);
        for bits in [2usize, 4, 8] {
            // dim large enough that per-row (scale, offset) overhead is small
            let q = QuantizedEmbedding::random(100, 512, bits, &mut rng);
            let rate = q.space_saving_rate();
            assert!(rate <= 32.0 / bits as f64 + 1e-9, "bits {bits}: rate {rate}");
            assert!(rate > 32.0 / bits as f64 * 0.8, "bits {bits}: rate {rate} too low");
        }
    }

    #[test]
    fn constant_row_handled() {
        let dense = vec![0.5f32; 4 * 8];
        let q = QuantizedEmbedding::from_dense(4, 8, &dense, 4);
        let rec = q.lookup(2);
        for x in rec {
            assert!((x - 0.5).abs() < 1e-6);
        }
    }
}
