//! Compressing a *pretrained* dense embedding matrix into word2ketXS form.
//!
//! The paper trains compressed embeddings from scratch; its related-work
//! section (§4.1) contrasts with methods that compress a trained table. This
//! module provides that missing workflow for order-2 word2ketXS: fit
//! `M ≈ Σ_{k≤r} F_1kᵀ ⊗ F_2kᵀ` to a given `d × p` matrix by the classic
//! Van Loan–Pitsianis reduction — the nearest Kronecker product problem is an
//! SVD of a rearrangement R(M), solved here with alternating least squares
//! (power iteration per rank, then deflation), which needs no external
//! LAPACK.
//!
//! With the fitted store, a pretrained GloVe-style table can be served from
//! `r·n·q·t` floats with quantifiable reconstruction error.

use super::word2ketxs::Word2KetXS;
use super::EmbeddingStore;
use crate::error::{Error, Result};
use crate::tensor::Tensor;
use crate::util::{ceil_root, Rng};

/// Result of a compression fit.
#[derive(Debug)]
pub struct FitReport {
    pub store: Word2KetXS,
    /// Relative Frobenius error ‖M − M̂‖_F / ‖M‖_F.
    pub rel_error: f64,
    /// Per-rank singular-value-like weights (descending).
    pub weights: Vec<f64>,
    pub iterations: usize,
}

/// Fit an order-2 word2ketXS store of rank `r` to a dense `d × p` matrix.
///
/// The matrix is zero-padded to `t² × q²` (t = ⌈√d⌉, q = ⌈√p⌉); the
/// rearrangement R maps each (t×q)-block of the padded matrix to a row, so
/// `M ≈ Σ_k a_k ⊗ b_k` becomes the best rank-r approximation of R(M).
pub fn fit_xs_order2(m: &Tensor, rank: usize, iters: usize, seed: u64) -> Result<FitReport> {
    if m.ndim() != 2 {
        return Err(Error::Shape("fit_xs_order2 expects a matrix".into()));
    }
    let (d, p) = (m.shape()[0], m.shape()[1]);
    let t = ceil_root(d, 2).max(2);
    let q = ceil_root(p, 2).max(2);

    // R(M): rows index the (i1, j1) outer block, columns the (i2, j2) inner
    // position. M[(i1*t + i2), (j1*q + j2)] → R[(i1*q? no: R[i1*? ...)]
    // Outer factor A is t×q (vocab-block × dim-block), inner factor B is t×q.
    // M̂[(i1 t + i2), (j1 q + j2)] = Σ_k A_k[i1, j1] · B_k[i2, j2].
    let rows = t * q; // number of (i1, j1) pairs
    let cols = t * q; // number of (i2, j2) pairs
    let mut r_mat = vec![0.0f64; rows * cols];
    for i1 in 0..t {
        for j1 in 0..q {
            let rrow = i1 * q + j1;
            for i2 in 0..t {
                for j2 in 0..q {
                    let (i, j) = (i1 * t + i2, j1 * q + j2);
                    if i < d && j < p {
                        r_mat[rrow * cols + (i2 * q + j2)] = m.at2(i, j) as f64;
                    }
                }
            }
        }
    }

    // Greedy rank-r SVD of R via power iteration + deflation.
    let mut rng = Rng::new(seed ^ 0xf17);
    let mut a_factors: Vec<Vec<f64>> = Vec::with_capacity(rank); // len rows
    let mut b_factors: Vec<Vec<f64>> = Vec::with_capacity(rank); // len cols
    let mut weights = Vec::with_capacity(rank);
    let mut resid = r_mat.clone();
    let mut total_iters = 0;
    for _k in 0..rank {
        let mut v: Vec<f64> = (0..cols).map(|_| rng.gaussian()).collect();
        normalize(&mut v);
        let mut u = vec![0.0f64; rows];
        let mut sigma = 0.0;
        for _ in 0..iters {
            total_iters += 1;
            // u = R v
            for (i, ui) in u.iter_mut().enumerate() {
                let row = &resid[i * cols..(i + 1) * cols];
                *ui = row.iter().zip(&v).map(|(&a, &b)| a * b).sum();
            }
            let un = normalize(&mut u);
            // v = Rᵀ u
            for vj in v.iter_mut() {
                *vj = 0.0;
            }
            for i in 0..rows {
                let ui = u[i];
                if ui != 0.0 {
                    let row = &resid[i * cols..(i + 1) * cols];
                    for (vj, &rij) in v.iter_mut().zip(row) {
                        *vj += ui * rij;
                    }
                }
            }
            sigma = normalize(&mut v);
            if un == 0.0 || sigma == 0.0 {
                break;
            }
        }
        // Deflate: resid -= σ u vᵀ.
        for i in 0..rows {
            let ui = sigma * u[i];
            if ui != 0.0 {
                let row = &mut resid[i * cols..(i + 1) * cols];
                for (rij, &vj) in row.iter_mut().zip(&v) {
                    *rij -= ui * vj;
                }
            }
        }
        weights.push(sigma);
        a_factors.push(u);
        b_factors.push(v);
    }

    // Assemble the store: distribute √σ into each side.
    let mut store = Word2KetXS::random(d, p, 2, rank, &mut rng);
    for k in 0..rank {
        let s = weights[k].max(0.0).sqrt();
        for i1 in 0..t {
            for j1 in 0..q {
                // outer factor: row index of R → A_k[i1, j1]; our storage is
                // column-major-by-vocab: factor_col(k, 0, i1)[j1].
                store.factor_col_mut(k, 0, i1)[j1] = (s * a_factors[k][i1 * q + j1]) as f32;
            }
        }
        for i2 in 0..t {
            for j2 in 0..q {
                store.factor_col_mut(k, 1, i2)[j2] = (s * b_factors[k][i2 * q + j2]) as f32;
            }
        }
    }

    // Relative error over the real (unpadded) region.
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..d {
        let approx = store.lookup(i);
        for j in 0..p {
            let x = m.at2(i, j) as f64;
            let e = x - approx[j] as f64;
            num += e * e;
            den += x * x;
        }
    }
    let rel_error = if den > 0.0 { (num / den).sqrt() } else { 0.0 };
    Ok(FitReport { store, rel_error, weights, iterations: total_iters })
}

fn normalize(v: &mut [f64]) -> f64 {
    let n = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kron::kron_mat;

    /// A matrix that *is* a Kronecker product must fit to ~zero error at rank 1.
    #[test]
    fn exact_kron_recovered_rank1() {
        let mut rng = Rng::new(1);
        // A: 3×2 (vocab side), B: 3×2 → M = A ⊗ B is 9×4 with d=9, p=4.
        let a = Tensor::new(vec![3, 2], rng.uniform_vec(6, -1.0, 1.0)).unwrap();
        let b = Tensor::new(vec![3, 2], rng.uniform_vec(6, -1.0, 1.0)).unwrap();
        let m = kron_mat(&a, &b);
        let fit = fit_xs_order2(&m, 1, 40, 0).unwrap();
        assert!(fit.rel_error < 1e-4, "rel error {}", fit.rel_error);
        // Lookup reproduces rows.
        let row = fit.store.lookup(5);
        for j in 0..4 {
            assert!((row[j] - m.at2(5, j)).abs() < 1e-3);
        }
    }

    #[test]
    fn rank2_beats_rank1_on_rank2_matrix() {
        let mut rng = Rng::new(2);
        let mk = |rng: &mut Rng| {
            let a = Tensor::new(vec![4, 3], rng.uniform_vec(12, -1.0, 1.0)).unwrap();
            let b = Tensor::new(vec![4, 3], rng.uniform_vec(12, -1.0, 1.0)).unwrap();
            kron_mat(&a, &b)
        };
        let m = mk(&mut rng).add(&mk(&mut rng)).unwrap();
        let f1 = fit_xs_order2(&m, 1, 40, 0).unwrap();
        let f2 = fit_xs_order2(&m, 2, 40, 0).unwrap();
        assert!(f2.rel_error < f1.rel_error * 0.5, "{} !< {}", f2.rel_error, f1.rel_error);
        assert!(f2.rel_error < 1e-3, "rank-2 should be near-exact: {}", f2.rel_error);
    }

    #[test]
    fn error_decreases_with_rank_on_random_matrix() {
        let mut rng = Rng::new(3);
        let m = Tensor::new(vec![30, 16], rng.uniform_vec(480, -1.0, 1.0)).unwrap();
        let errs: Vec<f64> = [1usize, 2, 4, 8]
            .iter()
            .map(|&r| fit_xs_order2(&m, r, 25, 0).unwrap().rel_error)
            .collect();
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "error not monotone: {errs:?}");
        }
        // Random matrices are hard; just require real progress.
        assert!(errs[3] < errs[0], "{errs:?}");
    }

    #[test]
    fn weights_descending() {
        let mut rng = Rng::new(4);
        let m = Tensor::new(vec![25, 9], rng.uniform_vec(225, -1.0, 1.0)).unwrap();
        let fit = fit_xs_order2(&m, 4, 25, 0).unwrap();
        for w in fit.weights.windows(2) {
            assert!(w[0] >= w[1] - 1e-9, "weights not descending: {:?}", fit.weights);
        }
    }

    #[test]
    fn nonsquare_and_padded_dims() {
        let mut rng = Rng::new(5);
        // d=10 (t=4, padded 16), p=5 (q=3, padded 9).
        let m = Tensor::new(vec![10, 5], rng.uniform_vec(50, -1.0, 1.0)).unwrap();
        let fit = fit_xs_order2(&m, 3, 25, 0).unwrap();
        assert_eq!(fit.store.vocab_size(), 10);
        assert_eq!(fit.store.dim(), 5);
        assert!(fit.rel_error.is_finite());
        assert_eq!(fit.store.lookup(9).len(), 5);
    }
}
