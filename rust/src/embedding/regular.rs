//! The baseline the paper compares against: a dense `d × p` matrix.

use super::EmbeddingStore;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Dense row-major embedding matrix.
#[derive(Debug, Clone)]
pub struct RegularEmbedding {
    vocab: usize,
    dim: usize,
    data: Vec<f32>,
}

impl RegularEmbedding {
    pub fn new(vocab: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), vocab * dim);
        RegularEmbedding { vocab, dim, data }
    }

    /// Glorot-uniform initialization, matching typical embedding init.
    pub fn random(vocab: usize, dim: usize, rng: &mut Rng) -> Self {
        let a = (3.0 / dim as f32).sqrt();
        RegularEmbedding { vocab, dim, data: rng.uniform_vec(vocab * dim, -a, a) }
    }

    /// Borrow the underlying matrix (used by the quantized/low-rank baselines
    /// when compressing a trained table).
    pub fn matrix(&self) -> Tensor {
        Tensor::new(vec![self.vocab, self.dim], self.data.clone()).unwrap()
    }

    pub fn row_slice(&self, id: usize) -> &[f32] {
        &self.data[id * self.dim..(id + 1) * self.dim]
    }

    /// The full row-major matrix as a flat slice (snapshot serialization).
    pub fn data(&self) -> &[f32] {
        &self.data
    }
}

impl EmbeddingStore for RegularEmbedding {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn num_params(&self) -> usize {
        self.data.len()
    }

    fn lookup(&self, id: usize) -> Vec<f32> {
        self.row_slice(id).to_vec()
    }

    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        out.copy_from_slice(self.row_slice(id));
    }

    fn lookup_batch_into(&self, ids: &[usize], out: &mut Vec<f32>) {
        // Rows are plain memcpys here, so straight copies beat dedup
        // bookkeeping.
        out.clear();
        out.reserve(ids.len() * self.dim);
        for &id in ids {
            out.extend_from_slice(self.row_slice(id));
        }
    }

    fn repr(&self) -> crate::repr::Repr<'_> {
        crate::repr::Repr::Regular(self)
    }

    fn describe(&self) -> String {
        format!("Regular {}×{} ({} params)", self.vocab, self.dim, self.num_params())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_equal_d_times_p() {
        let mut rng = Rng::new(0);
        let e = RegularEmbedding::random(100, 32, &mut rng);
        assert_eq!(e.num_params(), 3200);
        assert_eq!(e.space_saving_rate(), 1.0);
    }

    #[test]
    fn lookup_returns_stored_row() {
        let data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let e = RegularEmbedding::new(3, 4, data);
        assert_eq!(e.lookup(1), vec![4.0, 5.0, 6.0, 7.0]);
        let b = e.lookup_batch(&[2, 0]);
        assert_eq!(b.row(0), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(b.row(1), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn init_scale_bounded() {
        let mut rng = Rng::new(1);
        let e = RegularEmbedding::random(10, 64, &mut rng);
        let a = (3.0f32 / 64.0).sqrt();
        assert!(e.lookup(0).iter().all(|x| x.abs() <= a));
    }
}
