//! Embedding stores: the paper's two contributions plus related-work
//! baselines, behind one trait.
//!
//! These are the *serving-path* implementations (pure Rust): they back the
//! embedding server, the lookup benchmarks, the parameter accounting of
//! Tables 1–3, and act as independent oracles for the Pallas kernels. The
//! *training-path* versions of the same math live in `python/compile/` and
//! run as AOT-compiled XLA executables.

pub mod compress;
mod hashed;
mod lowrank;
// Crate-visible so the snapshot store can share the bit-unpacking helpers
// (identical decode ⇒ bit-identical reconstruction from a mapped file).
pub(crate) mod quantized;
mod regular;
pub mod stats;
mod word2ket;
mod word2ketxs;

pub use compress::{fit_xs_order2, FitReport};
pub use hashed::HashedEmbedding;
pub use lowrank::LowRankEmbedding;
pub use quantized::QuantizedEmbedding;
pub use regular::RegularEmbedding;
pub use word2ket::Word2Ket;
pub use word2ketxs::Word2KetXS;

use crate::config::{EmbeddingConfig, EmbeddingKind};
use crate::repr::Repr;
use crate::tensor::Tensor;
use crate::util::Rng;
use std::collections::{hash_map::Entry, HashMap};

/// Reconstruct rows for `ids` into `data` (resized to `ids.len() × dim`,
/// reusing its capacity), calling `fill` exactly once per distinct id and
/// copying its row to every later position that repeats it. Production
/// token streams are Zipf-skewed, so batches repeat head ids constantly and
/// duplicate reconstruction is pure waste. Shared by the trait default
/// `lookup_batch_into` and store-specific overrides; callers that keep the
/// arena alive across batches (the serving worker pool) pay zero
/// allocations in steady state.
///
/// `fill` must write its whole row: every position of `data` is either
/// filled or copied from its first occurrence below, so the arena is
/// deliberately *not* re-zeroed between batches (a per-drain memset of the
/// full batch would cost more than the dedup saves on hot streams).
pub(crate) fn dedup_scatter_into(
    ids: &[usize],
    dim: usize,
    data: &mut Vec<f32>,
    mut fill: impl FnMut(usize, &mut [f32]),
) {
    thread_local! {
        /// First-occurrence map, reused across batches on each thread
        /// (taken out of the cell while in use, so a `fill` that somehow
        /// re-enters just falls back to a fresh map instead of panicking).
        static FIRST_ROW: std::cell::Cell<HashMap<usize, usize>> =
            std::cell::Cell::new(HashMap::new());
    }
    // Shrinking writes nothing; growing zero-fills only the new tail.
    data.resize(ids.len() * dim, 0.0);
    let mut first_row = FIRST_ROW.with(std::cell::Cell::take);
    first_row.clear();
    first_row.reserve(ids.len());
    for (row, &id) in ids.iter().enumerate() {
        match first_row.entry(id) {
            Entry::Occupied(e) => {
                let src = *e.get();
                data.copy_within(src * dim..(src + 1) * dim, row * dim);
            }
            Entry::Vacant(e) => {
                e.insert(row);
                fill(id, &mut data[row * dim..(row + 1) * dim]);
            }
        }
    }
    FIRST_ROW.with(|cell| cell.set(first_row));
}

/// Allocating convenience over [`dedup_scatter_into`] (tests, one-shot
/// callers).
#[cfg(test)]
pub(crate) fn dedup_scatter(
    ids: &[usize],
    dim: usize,
    fill: impl FnMut(usize, &mut [f32]),
) -> Vec<f32> {
    let mut data = Vec::new();
    dedup_scatter_into(ids, dim, &mut data, fill);
    data
}

/// A `d × p` word-embedding matrix accessed row-wise.
pub trait EmbeddingStore: Send + Sync {
    /// Vocabulary size `d`.
    fn vocab_size(&self) -> usize;

    /// Embedding dimensionality `p`.
    fn dim(&self) -> usize;

    /// Number of trainable parameters actually stored.
    fn num_params(&self) -> usize;

    /// Reconstruct the embedding vector for one token id.
    fn lookup(&self, id: usize) -> Vec<f32>;

    /// Reconstruct row `id` into a caller-provided buffer of length
    /// [`dim`](Self::dim), bit-identical to [`lookup`](Self::lookup).
    ///
    /// This is the allocation-free serving primitive: every concrete store
    /// overrides it to write `out` directly (reusing per-thread scratch
    /// where reconstruction needs working space). The default exists for
    /// external store impls and simply copies the allocated `lookup` row.
    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        out.copy_from_slice(&self.lookup(id));
    }

    /// Reconstruct a batch of rows into a caller-provided arena (resized to
    /// `ids.len() × dim`, capacity reused across calls; every position is
    /// overwritten).
    ///
    /// The default reconstructs each distinct id once via
    /// [`lookup_into`](Self::lookup_into) and scatters the row to every
    /// position that repeats it (see `dedup_scatter_into`).
    fn lookup_batch_into(&self, ids: &[usize], out: &mut Vec<f32>) {
        dedup_scatter_into(ids, self.dim(), out, |id, row| self.lookup_into(id, row));
    }

    /// Reconstruct a batch of rows as a `(b, p)` tensor (allocating
    /// convenience over [`lookup_batch_into`](Self::lookup_batch_into)).
    fn lookup_batch(&self, ids: &[usize]) -> Tensor {
        let mut data = Vec::with_capacity(ids.len() * self.dim());
        self.lookup_batch_into(ids, &mut data);
        Tensor::new(vec![ids.len(), self.dim()], data).expect("lookup_batch shape")
    }

    /// Space saving rate vs a regular `d × p` matrix (paper's definition:
    /// regular parameter count divided by this store's parameter count).
    /// A store reporting zero parameters rates 0 (not `inf`/NaN), so
    /// report tables stay finite.
    fn space_saving_rate(&self) -> f64 {
        let params = self.num_params();
        if params == 0 {
            return 0.0;
        }
        (self.vocab_size() as f64 * self.dim() as f64) / params as f64
    }

    /// Human-readable description for reports.
    fn describe(&self) -> String;

    /// The store's typed representation (see [`crate::repr::Repr`]): the
    /// index scorer resolves factored-space scoring through this (including
    /// snapshot-backed stores after a hot swap), and `snapshot::save_store`
    /// dispatches serialization on it. Wrappers
    /// ([`crate::serving::ShardedCache`]) return [`Repr::Cached`] so
    /// [`Repr::resolve`] can peel them; every concrete store overrides this
    /// with its own variant. The default declares no identity.
    fn repr(&self) -> Repr<'_> {
        Repr::Opaque
    }
}

/// Materialize the full `d × p` matrix (tests / small vocabularies only).
pub fn materialize(store: &dyn EmbeddingStore) -> Tensor {
    let ids: Vec<usize> = (0..store.vocab_size()).collect();
    store.lookup_batch(&ids)
}

/// Construct a store from an [`EmbeddingConfig`] (used by the server and the
/// benches; training-path stores are built inside the AOT graphs instead).
pub fn build(
    cfg: &EmbeddingConfig,
    vocab: usize,
    dim: usize,
    rng: &mut Rng,
) -> Box<dyn EmbeddingStore> {
    match cfg.kind {
        EmbeddingKind::Regular => Box::new(RegularEmbedding::random(vocab, dim, rng)),
        EmbeddingKind::Word2Ket => {
            let mut e = Word2Ket::random(vocab, dim, cfg.order, cfg.rank, rng);
            e.set_layernorm(cfg.layernorm);
            Box::new(e)
        }
        EmbeddingKind::Word2KetXS => {
            Box::new(Word2KetXS::random(vocab, dim, cfg.order, cfg.rank, rng))
        }
        EmbeddingKind::Quantized => {
            Box::new(QuantizedEmbedding::random(vocab, dim, cfg.bits, rng))
        }
        EmbeddingKind::LowRank => {
            Box::new(LowRankEmbedding::random(vocab, dim, cfg.lowrank_dim, rng))
        }
        EmbeddingKind::Hashed => Box::new(HashedEmbedding::random(vocab, dim, cfg.buckets, rng)),
        EmbeddingKind::QuantizedKet => {
            // Quantize a fresh raw-CP word2ket store (LayerNorm never
            // applies — config validation rejects it, and the random
            // constructor starts raw). `from_word2ket` only fails on
            // unsupported widths or degenerate geometry, both of which
            // config validation rejects before a server gets here.
            let w = Word2Ket::random(vocab, dim, cfg.order, cfg.rank, rng);
            Box::new(
                crate::quant::QuantizedKet::from_word2ket(&w, cfg.bits)
                    .expect("quantized-ket geometry rejected by config validation"),
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EmbeddingConfig;

    #[test]
    fn build_dispatches_all_kinds() {
        let mut rng = Rng::new(0);
        for kind in [
            EmbeddingKind::Regular,
            EmbeddingKind::Word2Ket,
            EmbeddingKind::Word2KetXS,
            EmbeddingKind::Quantized,
            EmbeddingKind::LowRank,
            EmbeddingKind::Hashed,
            EmbeddingKind::QuantizedKet,
        ] {
            let cfg = EmbeddingConfig { kind, order: 2, rank: 2, ..Default::default() };
            let store = build(&cfg, 100, 16, &mut rng);
            assert_eq!(store.vocab_size(), 100);
            assert_eq!(store.dim(), 16);
            assert_eq!(store.lookup(7).len(), 16);
            assert!(store.num_params() > 0, "{}", store.describe());
        }
    }

    #[test]
    fn batch_dedup_scatters_repeats() {
        // Zipf-shaped batch with heavy repetition: every position must still
        // receive exactly its id's row, bit-identical to a single lookup.
        let mut rng = Rng::new(2);
        for kind in [EmbeddingKind::Word2KetXS, EmbeddingKind::Quantized] {
            let cfg = EmbeddingConfig { kind, order: 2, rank: 2, ..Default::default() };
            let store = build(&cfg, 40, 16, &mut rng);
            let ids = [7usize, 0, 7, 7, 3, 0, 39, 7];
            let batch = store.lookup_batch(&ids);
            assert_eq!(batch.shape(), &[8, 16]);
            for (row, &id) in ids.iter().enumerate() {
                assert_eq!(batch.row(row), store.lookup(id).as_slice(), "row {row} id {id}");
            }
        }
    }

    #[test]
    fn dedup_scatter_empty_ids() {
        let data = dedup_scatter(&[], 8, |_, _| panic!("fill must not run for empty ids"));
        assert!(data.is_empty());
    }

    #[test]
    fn dedup_scatter_all_duplicates_fill_once() {
        let mut fills = 0usize;
        let ids = [9usize; 6];
        let data = dedup_scatter(&ids, 3, |id, out| {
            fills += 1;
            assert_eq!(id, 9);
            out.copy_from_slice(&[1.0, 2.0, 3.0]);
        });
        assert_eq!(fills, 1, "all-duplicate batch must reconstruct once");
        assert_eq!(data.len(), 6 * 3);
        for row in data.chunks(3) {
            assert_eq!(row, &[1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn dedup_scatter_interleaved_repeats() {
        // Repeats arriving *after* other ids must copy the first occurrence's
        // row, not refill: a row's value is id*10 + position-of-first-fill.
        let ids = [4usize, 2, 4, 7, 2, 4];
        let mut order: Vec<usize> = Vec::new();
        let data = dedup_scatter(&ids, 2, |id, out| {
            order.push(id);
            out[0] = id as f32 * 10.0;
            out[1] = order.len() as f32;
        });
        assert_eq!(order, vec![4, 2, 7], "fill order must follow first occurrences");
        for (row, &id) in data.chunks(2).zip(&ids) {
            assert_eq!(row[0], id as f32 * 10.0, "id {id}");
            // Every repeat carries the same fill-sequence stamp as its first
            // occurrence — proof it was copied, not refilled.
            let first = ids.iter().position(|&x| x == id).unwrap();
            assert_eq!(row[1], data[first * 2 + 1], "id {id} not copied from first row");
        }
    }

    #[test]
    fn dedup_scatter_fill_exactly_once_per_distinct() {
        let ids = [0usize, 5, 0, 3, 5, 5, 0, 3, 8];
        let mut fills: HashMap<usize, usize> = HashMap::new();
        dedup_scatter(&ids, 4, |id, out| {
            *fills.entry(id).or_insert(0) += 1;
            out.fill(id as f32);
        });
        assert_eq!(fills.len(), 4, "one fill per distinct id");
        assert!(fills.values().all(|&n| n == 1), "{fills:?}");
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(1);
        let cfg = EmbeddingConfig {
            kind: EmbeddingKind::Word2KetXS,
            order: 2,
            rank: 3,
            ..Default::default()
        };
        let store = build(&cfg, 50, 16, &mut rng);
        let batch = store.lookup_batch(&[3, 17, 49]);
        assert_eq!(batch.shape(), &[3, 16]);
        assert_eq!(batch.row(1), store.lookup(17).as_slice());
    }
}
