//! Low-rank factorization baseline (related work §4.1, "PCA-based"):
//! `M ≈ U · V` with `U ∈ R^{d×k}`, `V ∈ R^{k×p}`. Storage `k(d + p)` — the
//! paper's point is that such methods are lower-bounded by `d + p` (at k=1),
//! which word2ketXS beats by orders of magnitude.

use super::EmbeddingStore;
use crate::tensor::dot;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct LowRankEmbedding {
    vocab: usize,
    dim: usize,
    k: usize,
    /// d×k row-major.
    u: Vec<f32>,
    /// p×k row-major (V stored transposed for contiguous dot products).
    vt: Vec<f32>,
}

impl LowRankEmbedding {
    pub fn random(vocab: usize, dim: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(k >= 1);
        let a = (3.0 / dim as f32).sqrt();
        // Split the scale between the two factors.
        let s = a.sqrt();
        LowRankEmbedding {
            vocab,
            dim,
            k,
            u: rng.uniform_vec(vocab * k, -s, s),
            vt: rng.uniform_vec(dim * k, -s, s),
        }
    }

    /// Rebuild from serialized factors (snapshot loading). Validates shapes
    /// instead of asserting, so a corrupt snapshot yields a typed error.
    pub fn from_parts(
        vocab: usize,
        dim: usize,
        k: usize,
        u: Vec<f32>,
        vt: Vec<f32>,
    ) -> crate::Result<Self> {
        if k == 0 {
            return Err(crate::Error::Snapshot("lowrank k must be >= 1".into()));
        }
        let want_u = vocab
            .checked_mul(k)
            .ok_or_else(|| crate::Error::Snapshot("lowrank geometry overflows".into()))?;
        let want_vt = dim
            .checked_mul(k)
            .ok_or_else(|| crate::Error::Snapshot("lowrank geometry overflows".into()))?;
        if u.len() != want_u || vt.len() != want_vt {
            return Err(crate::Error::Snapshot(format!(
                "lowrank parts mismatch: |U|={} (want {want_u}), |Vt|={} (want {want_vt})",
                u.len(),
                vt.len()
            )));
        }
        Ok(LowRankEmbedding { vocab, dim, k, u, vt })
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The `d × k` factor, row-major (snapshot serialization).
    pub fn u(&self) -> &[f32] {
        &self.u
    }

    /// The `p × k` transposed factor, row-major.
    pub fn vt(&self) -> &[f32] {
        &self.vt
    }

    fn u_row(&self, id: usize) -> &[f32] {
        &self.u[id * self.k..(id + 1) * self.k]
    }
}

impl EmbeddingStore for LowRankEmbedding {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn num_params(&self) -> usize {
        self.k * (self.vocab + self.dim)
    }

    fn lookup(&self, id: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.lookup_into(id, &mut out);
        out
    }

    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let u = self.u_row(id);
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot(u, &self.vt[j * self.k..(j + 1) * self.k]);
        }
    }

    fn repr(&self) -> crate::repr::Repr<'_> {
        crate::repr::Repr::LowRank(self)
    }

    fn describe(&self) -> String {
        format!(
            "LowRank k={} ({}×{}, {} params, {:.1}× saving)",
            self.k,
            self.vocab,
            self.dim,
            self.num_params(),
            self.space_saving_rate()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_is_k_times_d_plus_p() {
        let mut rng = Rng::new(0);
        let e = LowRankEmbedding::random(1000, 300, 4, &mut rng);
        assert_eq!(e.num_params(), 4 * 1300);
    }

    #[test]
    fn saving_bounded_by_dp_over_d_plus_p() {
        // Even at k=1 the saving rate cannot exceed d·p/(d+p) — the paper's
        // structural bound on PCA/parameter-sharing methods.
        let mut rng = Rng::new(1);
        let (d, p) = (118_655usize, 300usize);
        let e = LowRankEmbedding::random(d, p, 1, &mut rng);
        let bound = (d * p) as f64 / (d + p) as f64; // ≈ 299.2
        assert!(e.space_saving_rate() <= bound + 1e-6);
        assert!(e.space_saving_rate() > bound * 0.99);
        // word2ketXS order-4 rank-1 achieves 93,675 — far beyond this bound.
        assert!(93_675.0 > bound * 100.0);
    }

    #[test]
    fn lookup_is_u_times_v() {
        let mut rng = Rng::new(2);
        let e = LowRankEmbedding::random(6, 5, 3, &mut rng);
        let v = e.lookup(2);
        assert_eq!(v.len(), 5);
        // manual recompute
        for j in 0..5 {
            let manual: f32 = (0..3).map(|kk| e.u[2 * 3 + kk] * e.vt[j * 3 + kk]).sum();
            assert!((v[j] - manual).abs() < 1e-6);
        }
    }
}
