//! word2ket (paper §2.3, eq. 3): each word's embedding is its own entangled
//! tensor `v_i = Σ_{k=1..r} ⊗_{j=1..n} v_jk^{(i)}` with leaves `v_jk ∈ R^q`,
//! `q = ⌈p^{1/n}⌉`. Storage: `d · r · n · q` instead of `d · p`.

use super::EmbeddingStore;
use crate::kron::{tree_term, CpTensor};
use crate::repr::{kernels, FactorGeometry, FactoredRepr, Repr};
use crate::util::{ceil_root, Rng};

/// Per-word CP tensors sharing (rank, order, leaf dim).
#[derive(Debug, Clone)]
pub struct Word2Ket {
    vocab: usize,
    dim: usize,
    order: usize,
    rank: usize,
    leaf_dim: usize,
    words: Vec<CpTensor>,
    layernorm: bool,
}

impl Word2Ket {
    /// `dim` is the requested embedding dimension p; the reconstructed vector
    /// has dimension `q^n ≥ p` and is truncated to p (the paper picks p=q^n
    /// exactly; truncation generalizes to arbitrary p).
    pub fn random(vocab: usize, dim: usize, order: usize, rank: usize, rng: &mut Rng) -> Self {
        assert!(order >= 2, "word2ket needs order >= 2");
        // The repr-layer factor kernels use fixed MAX_ORDER slice buffers;
        // enforce the same bound `from_leaves` already validates.
        assert!(order <= crate::repr::MAX_ORDER, "word2ket supports order <= 16");
        let q = ceil_root(dim, order as u32).max(2);
        let words = (0..vocab)
            .map(|w| {
                let mut child = rng.fork(w as u64);
                CpTensor::random(rank, order, q, &mut child)
            })
            .collect();
        Word2Ket { vocab, dim, order, rank, leaf_dim: q, words, layernorm: false }
    }

    /// Rebuild from a flat leaf blob (snapshot loading): word `w`'s CP
    /// tensor occupies `leaves[w·r·n·q .. (w+1)·r·n·q]` in `CpTensor` leaf
    /// order (`(k·n + j)·q`). Validates geometry instead of asserting, so a
    /// corrupt snapshot yields a typed error rather than a panic.
    pub fn from_leaves(
        vocab: usize,
        dim: usize,
        order: usize,
        rank: usize,
        leaf_dim: usize,
        layernorm: bool,
        leaves: &[f32],
    ) -> crate::Result<Word2Ket> {
        if !(2..=16).contains(&order) || rank == 0 || leaf_dim == 0 {
            return Err(crate::Error::Snapshot(format!(
                "bad word2ket geometry: order={order} rank={rank} q={leaf_dim}"
            )));
        }
        let full = leaf_dim
            .checked_pow(order as u32)
            .ok_or_else(|| crate::Error::Snapshot("word2ket q^order overflows".into()))?;
        // q^n must cover dim, and minimal-root construction bounds it by
        // dim·2^n: reject hostile geometries that would make every
        // reconstruction allocate a q^n-sized buffer.
        if full < dim || full > dim.saturating_mul(1usize << order) {
            return Err(crate::Error::Snapshot(format!(
                "word2ket q^order = {full} inconsistent with dim {dim}"
            )));
        }
        let per_word = rank
            .checked_mul(order)
            .and_then(|x| x.checked_mul(leaf_dim))
            .ok_or_else(|| crate::Error::Snapshot("word2ket geometry overflows".into()))?;
        let want = vocab
            .checked_mul(per_word)
            .ok_or_else(|| crate::Error::Snapshot("word2ket geometry overflows".into()))?;
        if leaves.len() != want {
            return Err(crate::Error::Snapshot(format!(
                "word2ket leaf blob has {} values, expected {want}",
                leaves.len()
            )));
        }
        let words = leaves
            .chunks(per_word)
            .map(|c| {
                let mut t = CpTensor::zeros(rank, order, leaf_dim);
                t.leaves_mut().copy_from_slice(c);
                t.layernorm_nodes = layernorm;
                t
            })
            .collect();
        Ok(Word2Ket { vocab, dim, order, rank, leaf_dim, words, layernorm })
    }

    pub fn set_layernorm(&mut self, on: bool) {
        self.layernorm = on;
        for w in &mut self.words {
            w.layernorm_nodes = on;
        }
    }

    pub fn order(&self) -> usize {
        self.order
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn leaf_dim(&self) -> usize {
        self.leaf_dim
    }

    /// Access a word's CP tensor (e.g. for factored inner products).
    pub fn word(&self, id: usize) -> &CpTensor {
        &self.words[id]
    }

    /// Whether LayerNorm is applied at tree nodes (factored identities only
    /// hold for the raw CP form, so the index scorer checks this).
    pub fn layernorm(&self) -> bool {
        self.layernorm
    }

    /// True when `q^n == p` exactly, i.e. reconstruction is not truncated and
    /// the factored inner product equals the dense dot product of rows.
    pub fn exact_dim(&self) -> bool {
        self.leaf_dim.checked_pow(self.order as u32) == Some(self.dim)
    }

    /// Factored inner product between two words' embeddings without
    /// reconstruction (§2.3): `O(r² n q)` time, `O(1)` space.
    ///
    /// Only valid in raw CP form (LayerNorm off).
    pub fn inner(&self, a: usize, b: usize) -> f32 {
        self.words[a].inner(&self.words[b])
    }
}

impl EmbeddingStore for Word2Ket {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn num_params(&self) -> usize {
        // d · r · n · q
        self.vocab * self.rank * self.order * self.leaf_dim
    }

    fn lookup(&self, id: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; self.dim];
        self.lookup_into(id, &mut v);
        v
    }

    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        // Same balanced tree per rank term as `CpTensor::reconstruct`, but
        // each term accumulates straight into the (possibly truncated)
        // caller buffer instead of a `q^n` temporary that gets truncated.
        // The tree levels themselves still allocate: Fig. 1's balanced form
        // is the defined reconstruction (and the only one LayerNorm nodes
        // compose with), and a fused chain-accumulate was measured *slower*
        // (see the perf note in `CpTensor::reconstruct`) — word2ketXS, not
        // this per-word store, is the allocation-free serving hot path.
        debug_assert_eq!(out.len(), self.dim);
        out.fill(0.0);
        let word = &self.words[id];
        let mut leaves: [&[f32]; crate::repr::MAX_ORDER] = [&[]; crate::repr::MAX_ORDER];
        for k in 0..self.rank {
            for (j, leaf) in leaves.iter_mut().take(self.order).enumerate() {
                *leaf = word.leaf(k, j);
            }
            let term = tree_term(&leaves[..self.order], self.layernorm);
            kernels::add_assign(out, &term);
        }
    }

    fn repr(&self) -> Repr<'_> {
        Repr::Word2Ket(self)
    }

    fn describe(&self) -> String {
        format!(
            "word2ket order={} rank={} q={} ({}×{}, {} params, {:.0}× saving)",
            self.order,
            self.rank,
            self.leaf_dim,
            self.vocab,
            self.dim,
            self.num_params(),
            self.space_saving_rate()
        )
    }
}

/// Factored-space contract (see [`crate::repr`]). Handed out by
/// [`Repr::factored`] only in raw, untruncated form, where the §2.3 inner
/// products below equal dense dot products of reconstructed rows.
impl FactoredRepr for Word2Ket {
    fn geometry(&self) -> FactorGeometry {
        FactorGeometry { order: self.order, rank: self.rank, leaf_dim: self.leaf_dim }
    }

    fn factors<'s>(&'s self, id: usize, k: usize, out: &mut [&'s [f32]]) {
        // An overlong `out` would silently alias the next rank term's
        // leaves through the flat (k·n + j)·q offset math.
        debug_assert_eq!(out.len(), self.order);
        let word = &self.words[id];
        for (j, leaf) in out.iter_mut().enumerate() {
            *leaf = word.leaf(k, j);
        }
    }

    fn kind_name(&self) -> &'static str {
        "word2ket"
    }

    fn inner(&self, a: usize, b: usize) -> f32 {
        Word2Ket::inner(self, a, b)
    }

    fn block_inner(&self, a: usize, bs: &[usize], out: &mut [f32]) {
        // Hoist the query word's CP tensor out of the candidate loop; the
        // per-pair arithmetic is identical to `inner`.
        let wa = &self.words[a];
        for (o, &b) in out.iter_mut().zip(bs) {
            *o = wa.inner(&self.words[b]);
        }
    }

    fn write_row(&self, id: usize, out: &mut [f32]) {
        EmbeddingStore::lookup_into(self, id, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table1_row_w2k() {
        // Table 1: word2ket 4/1 dim 256 over GIGAWORD vocab 30,428 → 486,848
        // params = 30,428 · 1 · 4 · 4, saving rate 16.
        let mut rng = Rng::new(0);
        let e = Word2Ket::random(30_428, 256, 4, 1, &mut rng);
        assert_eq!(e.leaf_dim(), 4);
        assert_eq!(e.num_params(), 486_848);
        let rate = e.space_saving_rate();
        assert!((rate - 16.0).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn lookup_dim_and_determinism() {
        let mut rng = Rng::new(3);
        let e = Word2Ket::random(20, 27, 3, 2, &mut rng);
        let v1 = e.lookup(5);
        let v2 = e.lookup(5);
        assert_eq!(v1.len(), 27);
        assert_eq!(v1, v2);
        assert!(v1.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn factored_inner_matches_dense_lookup() {
        let mut rng = Rng::new(4);
        // p = q^n exactly so no truncation interferes: 4^2 = 16.
        let e = Word2Ket::random(10, 16, 2, 3, &mut rng);
        for (a, b) in [(0usize, 1usize), (2, 2), (5, 9)] {
            let va = e.lookup(a);
            let vb = e.lookup(b);
            let dense: f32 = va.iter().zip(vb.iter()).map(|(x, y)| x * y).sum();
            let fast = e.inner(a, b);
            assert!(
                (dense - fast).abs() < 1e-3 * dense.abs().max(1.0),
                "({a},{b}): dense {dense} vs factored {fast}"
            );
        }
    }

    #[test]
    fn layernorm_changes_reconstruction() {
        let mut rng = Rng::new(5);
        let mut e = Word2Ket::random(4, 16, 2, 2, &mut rng);
        let raw = e.lookup(0);
        e.set_layernorm(true);
        let ln = e.lookup(0);
        assert_eq!(raw.len(), ln.len());
        assert_ne!(raw, ln);
        assert!(ln.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn distinct_words_distinct_vectors() {
        let mut rng = Rng::new(6);
        let e = Word2Ket::random(8, 16, 2, 1, &mut rng);
        let v0 = e.lookup(0);
        let v1 = e.lookup(1);
        assert_ne!(v0, v1);
    }
}
