//! First-class factored-representation layer.
//!
//! The paper's entire advantage is that a word's vector *is* its factors —
//! `v = Σ_k ⊗_j v_jk` (§2.3) — yet every layer that wants to exploit that
//! (the index scorer, the serving cache, snapshot serialization) used to
//! rediscover it by `as_any()` downcasting through an ad-hoc chain of
//! concrete types. This module promotes the representation to a real API:
//!
//! * [`Repr`] — a typed identity every [`EmbeddingStore`] advertises via
//!   [`EmbeddingStore::repr`], replacing the old `as_any` escape hatch.
//!   Wrappers (the sharded hot-row cache) expose themselves as
//!   [`Repr::Cached`]; [`Repr::resolve`] peels them to the parameter-owning
//!   store underneath.
//! * [`FactoredRepr`] — the factored-space contract shared by
//!   [`Word2Ket`], [`Word2KetXS`], and the snapshot-mapped
//!   [`crate::snapshot::SnapshotStore`]: raw factor access
//!   ([`FactoredRepr::factors`]), pair and block inner products without
//!   reconstruction, and in-place row materialization
//!   ([`FactoredRepr::write_row`]). [`Repr::factored`] hands out the trait
//!   handle only when the factored identities actually hold (raw CP form,
//!   no LayerNorm, untruncated `q^n == p`).
//! * [`kernels`] — the shared slice-level routines (unrolled dot, axpy,
//!   truncating kron-accumulate, factor-product) every implementation
//!   routes through, so concrete stores and mapped snapshots stay
//!   bit-identical by construction.

pub mod kernels;

use crate::embedding::{
    EmbeddingStore, HashedEmbedding, LowRankEmbedding, QuantizedEmbedding, RegularEmbedding,
    Word2Ket, Word2KetXS,
};
use crate::quant::QuantizedKet;
use crate::serving::ShardedCache;
use crate::snapshot::SnapshotStore;

/// Upper bound on the tensor order any store exposes through
/// [`FactoredRepr`] (word2ket caps at 16, word2ketXS at 8); fixed so the
/// generic kernels can use stack arrays of factor slices.
pub const MAX_ORDER: usize = 16;

/// Shape of a factored representation: `rank` terms, each an order-`order`
/// tensor product of `leaf_dim`-long factors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FactorGeometry {
    /// Tensor order `n` (number of factors per rank term).
    pub order: usize,
    /// CP rank `r` (number of summed tensor-product terms).
    pub rank: usize,
    /// Per-factor length `q` (the embedding-side leaf dimension).
    pub leaf_dim: usize,
}

/// Factored-space access to an embedding store (see module docs).
///
/// Implementations guarantee that [`inner`](Self::inner) and
/// [`block_inner`](Self::block_inner) reproduce the dense dot product of
/// [`write_row`](Self::write_row) outputs bit-for-bit-deterministically
/// (same operation order as the historical per-store kernels), *provided*
/// the handle was obtained through [`Repr::factored`] — that gate checks
/// the raw-CP / untruncated preconditions under which the §2.3 identity
/// holds.
pub trait FactoredRepr {
    /// The factored shape.
    fn geometry(&self) -> FactorGeometry;

    /// Borrow the `order` factor slices of word `id`'s `k`-th rank term
    /// into `out` (callers pass `&mut slices[..order]`). Slice `j` is the
    /// paper's `v_jk` for this word: a per-word CP leaf for word2ket, the
    /// `i_j`-th factor column for word2ketXS.
    fn factors<'s>(&'s self, id: usize, k: usize, out: &mut [&'s [f32]]);

    /// Short name of the concrete representation (for `describe` strings).
    fn kind_name(&self) -> &'static str;

    /// Factored inner product `⟨row a, row b⟩` — `O(r² n q)` time, `O(1)`
    /// space, never materializing either row.
    fn inner(&self, a: usize, b: usize) -> f32 {
        let g = self.geometry();
        debug_assert!(g.order <= MAX_ORDER, "order {} exceeds MAX_ORDER", g.order);
        let mut fa: [&[f32]; MAX_ORDER] = [&[]; MAX_ORDER];
        let mut fb: [&[f32]; MAX_ORDER] = [&[]; MAX_ORDER];
        let mut total = 0.0f32;
        for k in 0..g.rank {
            self.factors(a, k, &mut fa[..g.order]);
            for k2 in 0..g.rank {
                self.factors(b, k2, &mut fb[..g.order]);
                total += kernels::product_of_dots(
                    fa[..g.order].iter().copied().zip(fb[..g.order].iter().copied()),
                );
            }
        }
        total
    }

    /// Block inner products: `out[i] = ⟨row a, row bs[i]⟩`. Scans resolve
    /// the representation once and then score whole candidate blocks
    /// through this, so per-pair dispatch never sits in the inner loop;
    /// implementations additionally hoist the query word's factor lookups
    /// out of the candidate loop. Results are bitwise equal to calling
    /// [`inner`](Self::inner) per pair.
    fn block_inner(&self, a: usize, bs: &[usize], out: &mut [f32]) {
        debug_assert_eq!(bs.len(), out.len());
        for (o, &b) in out.iter_mut().zip(bs) {
            *o = self.inner(a, b);
        }
    }

    /// Materialize row `id` into `out` (length = store dim), allocation-free
    /// where the representation allows. Same bytes as
    /// [`EmbeddingStore::lookup`].
    fn write_row(&self, id: usize, out: &mut [f32]);
}

/// Typed identity of an embedding store, replacing the old `as_any`
/// downcast chains. Each concrete store returns its own variant from
/// [`EmbeddingStore::repr`]; consumers `match` instead of downcasting.
#[derive(Clone, Copy)]
pub enum Repr<'a> {
    /// Dense baseline table.
    Regular(&'a RegularEmbedding),
    /// Per-word CP tensors (paper §2.3).
    Word2Ket(&'a Word2Ket),
    /// Shared-factor operator (paper §3.2).
    Word2KetXS(&'a Word2KetXS),
    /// Uniform-quantization baseline.
    Quantized(&'a QuantizedEmbedding),
    /// Low-rank factorization baseline.
    LowRank(&'a LowRankEmbedding),
    /// Hashing-trick baseline.
    Hashed(&'a HashedEmbedding),
    /// Sub-byte quantized word2ket payloads with an f16 refinement (see
    /// [`crate::quant`]). Its factored handle follows the *coarse
    /// contract*: `inner`/`block_inner` score in the quantized domain.
    QuantizedKet(&'a QuantizedKet),
    /// Snapshot-mapped store (any kind, served off the file).
    Snapshot(&'a SnapshotStore),
    /// The sharded hot-row cache wrapper; [`Repr::resolve`] peels it.
    Cached(&'a ShardedCache),
    /// A store that declares no identity (external trait impls); callers
    /// fall back to the dense [`EmbeddingStore`] surface.
    Opaque,
}

/// Peel wrapper stores (the hot-row cache) down to the parameter-owning
/// store. Shared by the index scorer's backend resolution and snapshot
/// serialization, so a new wrapper type only needs teaching here.
pub fn unwrap_wrappers(store: &dyn EmbeddingStore) -> &dyn EmbeddingStore {
    let mut cur = store;
    loop {
        match cur.repr() {
            Repr::Cached(cache) => cur = cache.inner(),
            _ => return cur,
        }
    }
}

impl<'a> Repr<'a> {
    /// The store's representation with wrappers peeled: what the old
    /// `unwrap_cached(store).as_any()` sniff chains reconstructed by hand.
    pub fn resolve(store: &'a dyn EmbeddingStore) -> Repr<'a> {
        unwrap_wrappers(store).repr()
    }

    /// The factored-space handle, if this representation supports the §2.3
    /// inner-product identity exactly: raw CP form (no LayerNorm) over the
    /// full `q^n` tensor (`q^n == p`, no truncation). Truncated or
    /// LayerNorm-ed stores return `None` and score densely.
    pub fn factored(self) -> Option<&'a dyn FactoredRepr> {
        match self {
            Repr::Word2Ket(w) if !w.layernorm() && w.exact_dim() => Some(w),
            Repr::Word2KetXS(xs) if xs.exact_dim() => Some(xs),
            // Quantized-ket handles score coarsely (`inner` is a
            // quantized-domain approximation — see `crate::quant`); callers
            // detect this via `payload_bits` and re-rank through rows.
            Repr::QuantizedKet(qk) if qk.exact_dim() => Some(qk),
            Repr::Snapshot(s) if s.factored() => Some(s),
            _ => None,
        }
    }

    /// Effective stored precision of the factor payload this representation
    /// scores with, in bits per value: 32 for float stores, 16/8 for
    /// f16/int8 snapshot payloads, and the packed code width for
    /// quantized-ket stores. Serving surfaces report it (the STATS
    /// `payload_bits` field / `w2k_payload_bits` gauge), and the IVF index
    /// treats `< 32` as "coarse scores — re-rank the top candidates through
    /// exact rows".
    pub fn payload_bits(self) -> usize {
        match self {
            Repr::QuantizedKet(qk) => qk.bits(),
            Repr::Quantized(q) => q.bits(),
            Repr::Snapshot(s) => s.payload_bits(),
            _ => 32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingConfig, EmbeddingKind};
    use crate::embedding::build;
    use crate::kron::kron_tree;
    use crate::snapshot::{save_store, SaveOptions, Snapshot, SnapshotStore};
    use crate::tensor::dot;
    use crate::util::Rng;
    use std::path::PathBuf;
    use std::sync::Arc;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("w2k_repr_test_{}_{}.snap", std::process::id(), name))
    }

    fn all_kinds() -> [EmbeddingKind; 7] {
        [
            EmbeddingKind::Regular,
            EmbeddingKind::Word2Ket,
            EmbeddingKind::Word2KetXS,
            EmbeddingKind::Quantized,
            EmbeddingKind::LowRank,
            EmbeddingKind::Hashed,
            EmbeddingKind::QuantizedKet,
        ]
    }

    /// Satellite acceptance: `lookup_into` is bit-exact with `lookup` for
    /// every kind, across randomized shapes including rank-1 and truncated
    /// (`q^n > p`) configurations, plain, cache-wrapped, and
    /// snapshot-backed.
    #[test]
    fn lookup_into_parity_all_kinds_and_wrappers() {
        // (vocab, dim, order, rank): dim 16 = 4² is exact for order 2;
        // dim 20 truncates (q=5, 25 > 20); dim 27 = 3³ exact for order 3;
        // rank 1 exercises the single-term path.
        let shapes = [(40usize, 16usize, 2usize, 2usize), (30, 20, 2, 1), (25, 27, 3, 3)];
        for (case, &(vocab, dim, order, rank)) in shapes.iter().enumerate() {
            for kind in all_kinds() {
                let cfg = EmbeddingConfig { kind, order, rank, ..Default::default() };
                let mut rng = Rng::new(100 + case as u64);
                let store = build(&cfg, vocab, dim, &mut rng);
                let check = |s: &dyn EmbeddingStore, label: &str| {
                    let mut out = vec![f32::NAN; dim];
                    for id in [0, vocab / 2, vocab - 1] {
                        s.lookup_into(id, &mut out);
                        let want = s.lookup(id);
                        assert_eq!(
                            want, out,
                            "{label} {kind:?} case {case} id {id}: lookup_into differs"
                        );
                    }
                };
                check(store.as_ref(), "plain");

                // Cache-wrapped: same rows through fetch_into.
                let mut rng = Rng::new(100 + case as u64);
                let twin = build(&cfg, vocab, dim, &mut rng);
                let cached = ShardedCache::new(twin, 2, 16);
                check(&cached, "cached");
                check(&cached, "cached-warm"); // second pass exercises hits

                // Snapshot-backed: zero-copy mapped store.
                let path = tmp(&format!("parity_{}_{case}", kind.name()));
                save_store(store.as_ref(), &path, &SaveOptions::default()).unwrap();
                let mm =
                    SnapshotStore::open(Arc::new(Snapshot::open(&path, true).unwrap())).unwrap();
                let mut out = vec![f32::NAN; dim];
                for id in [0, vocab - 1] {
                    mm.lookup_into(id, &mut out);
                    assert_eq!(store.lookup(id), out, "snapshot {kind:?} case {case} id {id}");
                }
                std::fs::remove_file(&path).ok();
            }
        }
    }

    /// Independent per-kind oracles: `lookup` now delegates to
    /// `lookup_into` for most kinds, so plain parity alone cannot catch a
    /// bug shared by both. Reconstruct rows through *different* code paths
    /// (the CP tree, public factor/code accessors, manual hash math) and
    /// compare.
    #[test]
    fn lookup_into_matches_independent_oracles() {
        let mut rng = Rng::new(31);

        // word2ket: full-tensor CP tree reconstruct, then truncate (the
        // pre-refactor lookup path, still live on CpTensor).
        let w2k = Word2Ket::random(12, 20, 2, 2, &mut rng);
        for id in [0usize, 11] {
            let mut out = vec![f32::NAN; 20];
            w2k.lookup_into(id, &mut out);
            let mut full = w2k.word(id).reconstruct();
            full.truncate(20);
            assert_eq!(full, out, "w2k id {id}");
        }

        // lowrank: manual u·vᵀ dots from the public factors.
        let lr = LowRankEmbedding::random(10, 6, 3, &mut rng);
        let mut out = vec![f32::NAN; 6];
        lr.lookup_into(4, &mut out);
        for (j, &got) in out.iter().enumerate() {
            let manual: f32 = (0..3).map(|c| lr.u()[4 * 3 + c] * lr.vt()[j * 3 + c]).sum();
            assert!((got - manual).abs() < 1e-6, "lowrank j {j}: {got} vs {manual}");
        }

        // hashed: manual splitmix64 bucket + sign from the public seed.
        let h = HashedEmbedding::random(9, 5, 7, &mut rng);
        let mut out = vec![f32::NAN; 5];
        h.lookup_into(3, &mut out);
        for (j, &got) in out.iter().enumerate() {
            let mut s = h.seed().wrapping_add(3u64 << 32).wrapping_add(j as u64);
            let x = crate::util::rng::splitmix64(&mut s);
            let sign = if (x >> 63) == 0 { 1.0 } else { -1.0 };
            assert_eq!(got, sign * h.weights()[(x % 7) as usize], "hashed j {j}");
        }

        // quantized: manual bit-unpack + dequantize from the public codes.
        let q = QuantizedEmbedding::random(8, 6, 5, &mut rng);
        let mut out = vec![f32::NAN; 6];
        q.lookup_into(2, &mut out);
        for (c, &got) in out.iter().enumerate() {
            let code = crate::embedding::quantized::get_bits(q.codes(), (2 * 6 + c) * 5, 5);
            assert_eq!(got, q.offsets()[2] + code as f32 * q.scales()[2], "quant c {c}");
        }
        // (word2ketXS is covered by `factors_reconstruct_rows` below:
        // kron_tree over the public factor columns.)
    }

    /// `write_row` on every factored repr agrees with `lookup` bit-exactly.
    #[test]
    fn write_row_parity_factored_reprs() {
        let mut rng = Rng::new(7);
        let w2k = Word2Ket::random(20, 16, 2, 2, &mut rng);
        let xs = Word2KetXS::random(20, 16, 2, 3, &mut rng);
        let path = tmp("write_row");
        save_store(&xs, &path, &SaveOptions::default()).unwrap();
        let mm = SnapshotStore::open(Arc::new(Snapshot::open(&path, true).unwrap())).unwrap();

        let stores: [(&dyn EmbeddingStore, &str); 3] =
            [(&w2k, "word2ket"), (&xs, "word2ketXS"), (&mm, "snapshot")];
        for (store, label) in stores {
            let f = Repr::resolve(store).factored().unwrap_or_else(|| panic!("{label} factored"));
            assert_eq!(f.kind_name(), label);
            let mut out = vec![f32::NAN; store.dim()];
            for id in [0usize, 7, 19] {
                f.write_row(id, &mut out);
                assert_eq!(store.lookup(id), out, "{label} id {id}");
            }
        }
        std::fs::remove_file(&path).ok();
    }

    /// `factors()` really exposes the §2.3 term factors: summing the
    /// Kronecker product of each rank term's slices reconstructs the row.
    #[test]
    fn factors_reconstruct_rows() {
        let mut rng = Rng::new(8);
        let xs = Word2KetXS::random(30, 16, 2, 2, &mut rng);
        let w2k = Word2Ket::random(12, 27, 3, 2, &mut rng);
        let stores: [&dyn EmbeddingStore; 2] = [&xs, &w2k];
        for store in stores {
            let f = Repr::resolve(store).factored().expect("factored");
            let g = f.geometry();
            let mut slices: [&[f32]; MAX_ORDER] = [&[]; MAX_ORDER];
            for id in [0usize, store.vocab_size() - 1] {
                let mut acc = vec![0.0f32; store.dim()];
                for k in 0..g.rank {
                    f.factors(id, k, &mut slices[..g.order]);
                    for s in &slices[..g.order] {
                        assert_eq!(s.len(), g.leaf_dim);
                    }
                    let term = kron_tree(&slices[..g.order]);
                    for (a, t) in acc.iter_mut().zip(&term) {
                        *a += t;
                    }
                }
                let want = store.lookup(id);
                for (a, w) in acc.iter().zip(&want) {
                    assert!(
                        (a - w).abs() < 1e-4 * w.abs().max(1.0),
                        "{} id {id}: {a} vs {w}",
                        f.kind_name()
                    );
                }
            }
        }
    }

    /// `block_inner` is bitwise `inner` per pair, and `inner` matches the
    /// dense dot of materialized rows on exact-dim stores.
    #[test]
    fn block_inner_matches_pairwise_and_dense() {
        let mut rng = Rng::new(9);
        let xs = Word2KetXS::random(50, 16, 2, 2, &mut rng);
        let w2k = Word2Ket::random(50, 16, 2, 3, &mut rng);
        let stores: [&dyn EmbeddingStore; 2] = [&xs, &w2k];
        for store in stores {
            let f = Repr::resolve(store).factored().expect("factored");
            let bs: Vec<usize> = vec![0, 7, 7, 49, 13];
            let mut block = vec![0.0f32; bs.len()];
            f.block_inner(3, &bs, &mut block);
            for (i, &b) in bs.iter().enumerate() {
                assert_eq!(
                    f.inner(3, b).to_bits(),
                    block[i].to_bits(),
                    "{} pair (3,{b})",
                    f.kind_name()
                );
                let dense = dot(&store.lookup(3), &store.lookup(b));
                assert!(
                    (dense - block[i]).abs() < 1e-4 * dense.abs().max(1.0),
                    "{} pair (3,{b}): dense {dense} vs factored {}",
                    f.kind_name(),
                    block[i]
                );
            }
        }
    }

    /// The trait-default `inner`/`block_inner` (built purely on
    /// `factors()`) carry the same bit-identity contract as the tuned
    /// per-store overrides: check them through a minimal adapter that
    /// provides only the required methods.
    #[test]
    fn default_inner_matches_overrides() {
        struct Bare<'a>(&'a Word2KetXS);
        impl FactoredRepr for Bare<'_> {
            fn geometry(&self) -> FactorGeometry {
                self.0.geometry()
            }
            fn factors<'s>(&'s self, id: usize, k: usize, out: &mut [&'s [f32]]) {
                // UFCS: the inherent zero-arg `Word2KetXS::factors` would
                // shadow the trait method under plain method syntax.
                FactoredRepr::factors(self.0, id, k, out)
            }
            fn kind_name(&self) -> &'static str {
                "bare"
            }
            fn write_row(&self, id: usize, out: &mut [f32]) {
                self.0.write_row(id, out)
            }
            // inner / block_inner: the trait defaults under test.
        }
        let mut rng = Rng::new(12);
        let xs = Word2KetXS::random(30, 16, 2, 3, &mut rng);
        let bare = Bare(&xs);
        for (a, b) in [(0usize, 1usize), (7, 7), (29, 3)] {
            assert_eq!(
                FactoredRepr::inner(&xs, a, b).to_bits(),
                bare.inner(a, b).to_bits(),
                "({a},{b})"
            );
        }
        let bs = [0usize, 7, 7, 29];
        let mut got = [0.0f32; 4];
        bare.block_inner(5, &bs, &mut got);
        for (i, &b) in bs.iter().enumerate() {
            assert_eq!(bare.inner(5, b).to_bits(), got[i].to_bits(), "block b={b}");
        }
    }

    /// The `Repr::factored` gate: truncated or LayerNorm-ed stores must not
    /// hand out a factored handle; wrappers resolve transparently.
    #[test]
    fn factored_gate_and_wrapper_resolution() {
        let mut rng = Rng::new(10);
        // 18² = 324 > 300: truncated.
        let trunc = Word2KetXS::random(40, 300, 2, 1, &mut rng);
        assert!(Repr::resolve(&trunc).factored().is_none());
        let mut ln = Word2Ket::random(10, 16, 2, 1, &mut rng);
        ln.set_layernorm(true);
        assert!(Repr::resolve(&ln).factored().is_none());
        let dense = RegularEmbedding::random(10, 8, &mut rng);
        assert!(Repr::resolve(&dense).factored().is_none());

        // Double-wrapped cache still resolves to the inner store.
        let inner = Box::new(Word2KetXS::random(30, 16, 2, 2, &mut rng));
        let cached = ShardedCache::new(Box::new(ShardedCache::new(inner, 2, 8)), 2, 8);
        assert!(matches!(Repr::resolve(&cached), Repr::Word2KetXS(_)));
        assert!(Repr::resolve(&cached).factored().is_some());
        assert!(matches!(cached.repr(), Repr::Cached(_)));
    }

    /// `payload_bits` reports the stored factor precision: packed code
    /// width for quantized payloads, 32 for everything served as f32.
    #[test]
    fn payload_bits_reports_stored_precision() {
        let mut rng = Rng::new(11);
        let w2k = Word2Ket::random(10, 16, 2, 2, &mut rng);
        assert_eq!(Repr::resolve(&w2k).payload_bits(), 32);
        let qk = crate::quant::QuantizedKet::from_word2ket(&w2k, 2).unwrap();
        assert_eq!(Repr::resolve(&qk).payload_bits(), 2);
        let cached = ShardedCache::new(Box::new(qk), 2, 8);
        assert_eq!(Repr::resolve(&cached).payload_bits(), 2);
        let q = QuantizedEmbedding::random(8, 6, 5, &mut rng);
        assert_eq!(Repr::resolve(&q).payload_bits(), 5);
        assert_eq!(Repr::Opaque.payload_bits(), 32);
    }

    /// Satellite acceptance: `space_saving_rate` must not divide by zero
    /// when a store reports no parameters.
    #[test]
    fn space_saving_rate_guards_zero_params() {
        struct Empty;
        impl EmbeddingStore for Empty {
            fn vocab_size(&self) -> usize {
                10
            }
            fn dim(&self) -> usize {
                4
            }
            fn num_params(&self) -> usize {
                0
            }
            fn lookup(&self, _id: usize) -> Vec<f32> {
                vec![0.0; 4]
            }
            fn describe(&self) -> String {
                "empty".into()
            }
        }
        let rate = Empty.space_saving_rate();
        assert!(rate.is_finite(), "rate {rate} must be finite");
        assert_eq!(rate, 0.0);
        // And an external store with no repr() override is Opaque.
        assert!(matches!(Empty.repr(), Repr::Opaque));
        assert!(Repr::resolve(&Empty).factored().is_none());
    }
}
