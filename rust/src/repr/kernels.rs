//! Shared lookup/scoring kernels for factored representations.
//!
//! One home for the slice-level routines that were previously duplicated
//! across `kron/` (the fused final level of `kron_accumulate`),
//! `embedding/word2ketxs.rs` (the fused order-2 outer product), and
//! `snapshot/store.rs` (the mapped mirror of both): the chunked/unrolled
//! dot product, the axpy accumulate, the truncating Kronecker
//! row-accumulate, and the `Π_j ⟨·,·⟩` factor-product behind every
//! factored inner product (paper §2.3). Every caller routes through these
//! — and since the SIMD swap that centralization was for has now landed,
//! the four slice primitives (`dot`, `axpy`, `add_assign`,
//! `kron2_accumulate`) delegate to the runtime-dispatched kernels in
//! [`crate::simd`] (scalar / SSE2 / AVX2, selected per CPU at startup,
//! bit-identical across levels by contract). The concrete stores and the
//! snapshot-mapped store stay *bit-identical* by construction instead of
//! by parallel maintenance.
//!
//! Also hosts the per-thread reconstruction scratch
//! ([`with_lookup_scratch`]) that makes the trait-level
//! [`crate::embedding::EmbeddingStore::lookup_into`] allocation-free in
//! steady state without widening its signature.

use crate::kron::KronScratch;
use std::cell::RefCell;

/// Dot product of two equal-length slices.
///
/// Delegates to [`crate::simd::dot`]: a pinned 8-lane association order
/// (identical bits at every dispatch level — scalar, SSE2, AVX2). This is
/// the primitive under every factored inner product and every dense
/// re-rank; [`crate::tensor::dot`] delegates here.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    crate::simd::dot(a, b)
}

/// `y += alpha · x` over the zip of the two slices (stops at the shorter).
/// Runtime-dispatched via [`crate::simd::axpy`]; elementwise, so every
/// dispatch level produces identical bits.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    crate::simd::axpy(alpha, x, y)
}

/// `acc += src` elementwise over the zip (stops at the shorter slice —
/// word2ket reconstructions accumulate a `q^n`-long term into a `p`-long
/// truncated row through exactly this). Runtime-dispatched via
/// [`crate::simd::add_assign`].
#[inline]
pub fn add_assign(acc: &mut [f32], src: &[f32]) {
    crate::simd::add_assign(acc, src)
}

/// Truncating Kronecker accumulate of two vectors:
/// `acc[i·q .. (i+1)·q] += a[i] · b` for every block that fits in both `a`
/// and `acc` (`q = |b|`; the last block may be partial — word2ketXS
/// truncates `q^n ≥ p` reconstructions to `p`).
///
/// Runtime-dispatched via [`crate::simd::kron2_accumulate`]. Two semantic
/// notes versus the original scalar loop:
///
/// * **Hardened block count.** The loop is clamped to `a.len()` blocks, so
///   an `acc` longer than `a.len() · q` — a hostile or short factor from a
///   snapshot-loaded geometry — leaves the uncovered suffix untouched
///   instead of panicking a worker on an out-of-bounds `a[i]`.
/// * **Dense.** Zero entries of `a` no longer skip their block: a vector
///   kernel can't cheaply skip, and skipping changes bits in `-0.0`/`NaN`
///   corners, which would break the cross-level parity contract.
#[inline]
pub fn kron2_accumulate(a: &[f32], b: &[f32], acc: &mut [f32]) {
    crate::simd::kron2_accumulate(a, b, acc)
}

/// `Π_j ⟨x_j, y_j⟩` over a stream of slice pairs, with the early-out on a
/// zero partial product every factored inner product in this codebase has
/// always used. One `(k, k')` rank-pair term of §2.3's
/// `⟨v, w⟩ = Σ_{k,k'} Π_j ⟨v_jk, w_jk'⟩`.
#[inline]
pub fn product_of_dots<'a>(pairs: impl Iterator<Item = (&'a [f32], &'a [f32])>) -> f32 {
    let mut prod = 1.0f32;
    for (x, y) in pairs {
        prod *= dot(x, y);
        if prod == 0.0 {
            break;
        }
    }
    prod
}

/// `Σ_{k,k'} term(k, k')` — the rank-pair accumulation shell of §2.3's
/// factored inner product (`term` is one `Π_j ⟨·,·⟩`, usually
/// [`product_of_dots`]). Shared by the in-memory stores and the
/// snapshot-mapped mirrors so the accumulation order — and therefore the
/// pre/post-hot-swap bit-identity of scores — is fixed in exactly one
/// place.
#[inline]
pub fn rank_pair_sum(
    rank_a: usize,
    rank_b: usize,
    mut term: impl FnMut(usize, usize) -> f32,
) -> f32 {
    let mut total = 0.0f32;
    for k in 0..rank_a {
        for k2 in 0..rank_b {
            total += term(k, k2);
        }
    }
    total
}

/// Factored inner product over already-decoded mixed-radix digits:
/// `Σ_{k,k'} Π_j ⟨col(k, j, da_j), col(k', j, db_j)⟩`. One home for the
/// digit-indexed shared-factor kernel so the in-memory word2ketXS store and
/// its snapshot-mapped mirror cannot drift (`col` is the only per-store
/// piece: a factor-column accessor).
#[inline]
pub fn factored_digit_inner<'a>(
    rank: usize,
    order: usize,
    da: &[usize; 8],
    db: &[usize; 8],
    col: impl Fn(usize, usize, usize) -> &'a [f32],
) -> f32 {
    rank_pair_sum(rank, rank, |k, k2| {
        product_of_dots((0..order).map(|j| (col(k, j, da[j]), col(k2, j, db[j]))))
    })
}

/// Block form of [`factored_digit_inner`]: the query word's digits are
/// decoded once for the whole candidate block, each `out[i]` is bitwise
/// what the pairwise call would produce.
#[inline]
pub fn factored_digit_block<'a>(
    rank: usize,
    order: usize,
    decode: impl Fn(usize, &mut [usize; 8]),
    col: impl Fn(usize, usize, usize) -> &'a [f32],
    a: usize,
    bs: &[usize],
    out: &mut [f32],
) {
    debug_assert_eq!(bs.len(), out.len());
    let mut da = [0usize; 8];
    let mut db = [0usize; 8];
    decode(a, &mut da);
    for (o, &b) in out.iter_mut().zip(bs) {
        decode(b, &mut db);
        *o = factored_digit_inner(rank, order, &da, &db, &col);
    }
}

/// Reusable per-thread buffers for allocation-free row reconstruction:
/// mixed-radix digits plus the Kronecker ping-pong scratch.
#[derive(Debug, Default)]
pub struct LookupScratch {
    /// Mixed-radix digit buffer (stores cap order at 16 or below).
    pub digits: [usize; 16],
    /// Ping-pong buffers for `kron_accumulate` (order ≥ 3 chains).
    pub kron: KronScratch,
}

thread_local! {
    static LOOKUP_SCRATCH: RefCell<LookupScratch> = RefCell::new(LookupScratch::default());
}

/// Run `f` with this thread's [`LookupScratch`]. After the first call on a
/// thread the scratch buffers are warm, so `lookup_into` reconstruction
/// allocates nothing in steady state. Do not call `with_lookup_scratch`
/// re-entrantly from inside `f` (single `RefCell` per thread).
pub fn with_lookup_scratch<R>(f: impl FnOnce(&mut LookupScratch) -> R) -> R {
    LOOKUP_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        // Lengths around the unroll boundary, including 0 and 1.
        for n in [0usize, 1, 3, 4, 5, 8, 17] {
            let a: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5 - 1.0).collect();
            let b: Vec<f32> = (0..n).map(|i| 2.0 - (i as f32) * 0.25).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-5, "n={n}");
        }
    }

    #[test]
    fn axpy_accumulates_prefix() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
    }

    #[test]
    fn add_assign_truncates_to_acc() {
        let mut acc = [1.0f32, 1.0];
        add_assign(&mut acc, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(acc, [2.0, 3.0]);
    }

    #[test]
    fn kron2_matches_dense_outer_product() {
        let a = [2.0f32, 0.0, -1.0];
        let b = [1.0f32, 3.0];
        // Full (untruncated) accumulate equals the dense Kronecker product.
        let mut acc = [0.0f32; 6];
        kron2_accumulate(&a, &b, &mut acc);
        assert_eq!(acc, [2.0, 6.0, 0.0, 0.0, -1.0, -3.0]);
        // Truncated accumulate covers only the prefix blocks.
        let mut short = [0.0f32; 5];
        kron2_accumulate(&a, &b, &mut short);
        assert_eq!(short, [2.0, 6.0, 0.0, 0.0, -1.0]);
        // Empty b: nothing to do (and no infinite loop).
        kron2_accumulate(&a, &[], &mut acc);
    }

    #[test]
    fn kron2_tolerates_acc_longer_than_outer_product() {
        // Regression: `acc.len() > a.len() * b.len()` used to walk off the
        // end of `a` (snapshot-loaded geometry could panic a worker). The
        // covered prefix accumulates; the suffix is left untouched.
        let a = [2.0f32, -1.0];
        let b = [1.0f32, 3.0];
        let mut acc = [7.0f32; 7];
        kron2_accumulate(&a, &b, &mut acc);
        assert_eq!(acc, [9.0, 13.0, 6.0, 4.0, 7.0, 7.0, 7.0]);
    }

    #[test]
    fn product_of_dots_zero_short_circuits() {
        let a = [1.0f32, 0.0];
        let z = [0.0f32, 0.0];
        let poison = [f32::NAN, f32::NAN];
        // The zero factor stops evaluation before the NaN pair is touched.
        let pairs = [(&a[..], &z[..]), (&poison[..], &poison[..])];
        let p = product_of_dots(pairs.iter().copied());
        assert_eq!(p, 0.0);
        // Non-degenerate product multiplies through.
        let b = [2.0f32, 1.0];
        let p = product_of_dots([(&a[..], &b[..]), (&b[..], &b[..])].iter().copied());
        assert_eq!(p, 2.0 * 5.0);
    }

    #[test]
    fn lookup_scratch_reuses_per_thread() {
        let first = with_lookup_scratch(|s| {
            s.digits[0] = 41;
            s.digits.as_ptr() as usize
        });
        let again = with_lookup_scratch(|s| {
            assert_eq!(s.digits[0], 41, "scratch must persist across calls");
            s.digits.as_ptr() as usize
        });
        assert_eq!(first, again, "same thread must reuse the same buffers");
    }
}
