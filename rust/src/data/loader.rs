//! Background prefetching over a batch stream (tokio substitute: one
//! std::thread producer + bounded mpsc channel). Keeps the PJRT step from
//! stalling on batch assembly — the L3 contribution of keeping Python (and
//! everything slow) off the hot path extends to batch prep too.

use std::sync::mpsc;
use std::thread::JoinHandle;

/// A prefetching iterator adapter: runs `make_items` on a worker thread and
/// buffers up to `depth` items ahead of the consumer.
pub struct Prefetcher<T: Send + 'static> {
    rx: mpsc::Receiver<T>,
    handle: Option<JoinHandle<()>>,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// Spawn a producer that pushes items from `producer` into a bounded
    /// queue of `depth`.
    pub fn spawn<F>(depth: usize, producer: F) -> Prefetcher<T>
    where
        F: FnOnce(&mut dyn FnMut(T) -> bool) + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let handle = std::thread::Builder::new()
            .name("w2k-prefetch".into())
            .spawn(move || {
                let mut push = |item: T| tx.send(item).is_ok();
                producer(&mut push);
            })
            .expect("spawn prefetch thread");
        Prefetcher { rx, handle: Some(handle) }
    }

    /// Convenience: prefetch a pre-built vector (moves batch assembly cost
    /// off the training thread when construction itself is the cost).
    pub fn from_vec(depth: usize, items: Vec<T>) -> Prefetcher<T> {
        Self::spawn(depth, move |push| {
            for it in items {
                if !push(it) {
                    break;
                }
            }
        })
    }
}

impl<T: Send + 'static> Iterator for Prefetcher<T> {
    type Item = T;

    fn next(&mut self) -> Option<T> {
        self.rx.recv().ok()
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // Close the channel, then join the producer.
        // Draining is unnecessary: sender errors out once rx is dropped,
        // but rx drops only after this; explicitly unblock by reading the
        // remaining items non-blockingly.
        while self.rx.try_recv().is_ok() {}
        if let Some(h) = self.handle.take() {
            // The producer may be blocked on a full channel; dropping rx
            // first is impossible here, so keep draining until it finishes.
            loop {
                match self.rx.try_recv() {
                    Ok(_) => continue,
                    Err(mpsc::TryRecvError::Empty) => {
                        if h.is_finished() {
                            break;
                        }
                        std::thread::yield_now();
                    }
                    Err(mpsc::TryRecvError::Disconnected) => break,
                }
            }
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yields_all_items_in_order() {
        let p = Prefetcher::from_vec(2, vec![1, 2, 3, 4, 5]);
        let got: Vec<i32> = p.collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn producer_runs_ahead() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let produced = Arc::new(AtomicUsize::new(0));
        let pc = produced.clone();
        let mut p = Prefetcher::spawn(4, move |push| {
            for i in 0..8 {
                pc.fetch_add(1, Ordering::SeqCst);
                if !push(i) {
                    break;
                }
            }
        });
        // Consume one item slowly; producer should have buffered ahead.
        let first = p.next().unwrap();
        assert_eq!(first, 0);
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(produced.load(Ordering::SeqCst) >= 4, "producer did not run ahead");
        let rest: Vec<usize> = p.collect();
        assert_eq!(rest, vec![1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn early_drop_terminates_producer() {
        let p = Prefetcher::from_vec(1, (0..1_000_000).collect::<Vec<usize>>());
        drop(p); // must not hang
    }

    #[test]
    fn empty_stream() {
        let p = Prefetcher::from_vec(2, Vec::<u8>::new());
        assert_eq!(p.count(), 0);
    }
}
