//! Length-bucketed, padded batching.
//!
//! Batches are padded to fixed maximum lengths because each AOT-compiled XLA
//! executable has static shapes. Length bucketing (sorting a shuffled window
//! by source length) minimizes padding waste without destroying shuffle
//! randomness — the standard seq2seq recipe.

use super::{EncodedPair, EncodedQa};
use crate::text::PAD;
use crate::util::Rng;

/// A padded seq2seq batch, row-major `[batch, len]` id matrices.
#[derive(Debug, Clone)]
pub struct Batch {
    pub src: Vec<i64>,
    pub tgt: Vec<i64>,
    /// 1.0 where tgt token is real (excluding the BOS position offset),
    /// 0.0 on padding; used for masked loss.
    pub tgt_mask: Vec<f32>,
    pub batch_size: usize,
    pub src_len: usize,
    pub tgt_len: usize,
}

/// A padded QA batch.
#[derive(Debug, Clone)]
pub struct QaBatch {
    pub context: Vec<i64>,
    pub question: Vec<i64>,
    pub start: Vec<i64>,
    pub end: Vec<i64>,
    pub batch_size: usize,
    pub ctx_len: usize,
    pub q_len: usize,
}

fn pad_to(ids: &[usize], len: usize) -> impl Iterator<Item = i64> + '_ {
    ids.iter()
        .take(len)
        .map(|&x| x as i64)
        .chain(std::iter::repeat(PAD as i64))
        .take(len)
}

/// Seq2seq batcher with shuffling and length bucketing. Emits fixed-size
/// batches (the last partial batch is padded by repeating examples, keeping
/// executable shapes static; repeated rows are masked out of metrics by the
/// caller via `real_rows`).
#[derive(Debug)]
pub struct Batcher {
    data: Vec<EncodedPair>,
    batch_size: usize,
    src_len: usize,
    tgt_len: usize,
    /// Bucketing window = bucket_mult × batch_size.
    bucket_mult: usize,
}

impl Batcher {
    pub fn new(data: Vec<EncodedPair>, batch_size: usize, src_len: usize, tgt_len: usize) -> Self {
        assert!(batch_size > 0);
        Batcher { data, batch_size, src_len, tgt_len, bucket_mult: 8 }
    }

    pub fn len_examples(&self) -> usize {
        self.data.len()
    }

    pub fn batches_per_epoch(&self) -> usize {
        crate::util::ceil_div(self.data.len(), self.batch_size)
    }

    /// One epoch of batches: shuffle, bucket by length, emit padded batches.
    /// `real_rows[i]` rows of batch i are genuine; the rest are repeats.
    pub fn epoch(&self, rng: &mut Rng) -> Vec<(Batch, usize)> {
        let mut order: Vec<usize> = (0..self.data.len()).collect();
        rng.shuffle(&mut order);
        // Bucket: within windows of bucket_mult×batch, sort by src length.
        let window = self.bucket_mult * self.batch_size;
        for chunk in order.chunks_mut(window) {
            chunk.sort_by_key(|&i| self.data[i].src.len());
        }
        let mut out = Vec::with_capacity(self.batches_per_epoch());
        for chunk in order.chunks(self.batch_size) {
            let real = chunk.len();
            let mut idx: Vec<usize> = chunk.to_vec();
            while idx.len() < self.batch_size {
                idx.push(chunk[idx.len() % real]); // repeat to fill
            }
            out.push((self.make_batch(&idx), real));
        }
        out
    }

    /// Sequential (unshuffled) batches for evaluation; returns per-batch
    /// original example indices alongside.
    pub fn eval_batches(&self) -> Vec<(Batch, Vec<usize>)> {
        let order: Vec<usize> = (0..self.data.len()).collect();
        let mut out = Vec::new();
        for chunk in order.chunks(self.batch_size) {
            let mut idx = chunk.to_vec();
            while idx.len() < self.batch_size {
                idx.push(chunk[idx.len() % chunk.len()]);
            }
            out.push((self.make_batch(&idx), chunk.to_vec()));
        }
        out
    }

    fn make_batch(&self, idx: &[usize]) -> Batch {
        let b = idx.len();
        let mut src = Vec::with_capacity(b * self.src_len);
        let mut tgt = Vec::with_capacity(b * self.tgt_len);
        let mut mask = Vec::with_capacity(b * self.tgt_len);
        for &i in idx {
            let ex = &self.data[i];
            src.extend(pad_to(&ex.src, self.src_len));
            tgt.extend(pad_to(&ex.tgt, self.tgt_len));
            let real = ex.tgt.len().min(self.tgt_len);
            // Loss positions: predicting tgt[1..real] (BOS excluded) → real-1
            // positions are live.
            for t in 0..self.tgt_len {
                mask.push(if t + 1 < real { 1.0 } else { 0.0 });
            }
        }
        Batch {
            src,
            tgt,
            tgt_mask: mask,
            batch_size: b,
            src_len: self.src_len,
            tgt_len: self.tgt_len,
        }
    }
}

/// QA batcher (contexts + questions + span labels).
#[derive(Debug)]
pub struct QaBatcher {
    data: Vec<EncodedQa>,
    batch_size: usize,
    ctx_len: usize,
    q_len: usize,
}

impl QaBatcher {
    pub fn new(data: Vec<EncodedQa>, batch_size: usize, ctx_len: usize, q_len: usize) -> Self {
        assert!(batch_size > 0);
        QaBatcher { data, batch_size, ctx_len, q_len }
    }

    pub fn len_examples(&self) -> usize {
        self.data.len()
    }

    pub fn batches_per_epoch(&self) -> usize {
        crate::util::ceil_div(self.data.len(), self.batch_size)
    }

    pub fn epoch(&self, rng: &mut Rng) -> Vec<(QaBatch, usize)> {
        let mut order: Vec<usize> = (0..self.data.len()).collect();
        rng.shuffle(&mut order);
        let mut out = Vec::with_capacity(self.batches_per_epoch());
        for chunk in order.chunks(self.batch_size) {
            let real = chunk.len();
            let mut idx = chunk.to_vec();
            while idx.len() < self.batch_size {
                idx.push(chunk[idx.len() % real]);
            }
            out.push((self.make_batch(&idx), real));
        }
        out
    }

    /// Sequential (unshuffled) batches for evaluation.
    pub fn eval_batches(&self) -> Vec<(QaBatch, usize)> {
        let order: Vec<usize> = (0..self.data.len()).collect();
        let mut out = Vec::new();
        for chunk in order.chunks(self.batch_size) {
            let real = chunk.len();
            let mut idx = chunk.to_vec();
            while idx.len() < self.batch_size {
                idx.push(chunk[idx.len() % real]);
            }
            out.push((self.make_batch(&idx), real));
        }
        out
    }

    fn make_batch(&self, idx: &[usize]) -> QaBatch {
        let b = idx.len();
        let mut context = Vec::with_capacity(b * self.ctx_len);
        let mut question = Vec::with_capacity(b * self.q_len);
        let mut start = Vec::with_capacity(b);
        let mut end = Vec::with_capacity(b);
        for &i in idx {
            let ex = &self.data[i];
            context.extend(pad_to(&ex.context, self.ctx_len));
            question.extend(pad_to(&ex.question, self.q_len));
            start.push(ex.span.0 as i64);
            end.push((ex.span.1 - 1) as i64); // inclusive end index for the model
        }
        QaBatch {
            context,
            question,
            start,
            end,
            batch_size: b,
            ctx_len: self.ctx_len,
            q_len: self.q_len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::{BOS, EOS};

    fn pair(src_len: usize, tag: usize) -> EncodedPair {
        EncodedPair {
            src: (0..src_len).map(|i| 4 + (i + tag) % 10).collect(),
            tgt: {
                let mut t = vec![BOS];
                t.extend((0..3).map(|i| 4 + (i + tag) % 10));
                t.push(EOS);
                t
            },
        }
    }

    #[test]
    fn fixed_shapes_and_padding() {
        let data = vec![pair(3, 0), pair(7, 1), pair(5, 2)];
        let b = Batcher::new(data, 2, 8, 6);
        let mut rng = Rng::new(0);
        let batches = b.epoch(&mut rng);
        assert_eq!(batches.len(), 2);
        for (batch, _real) in &batches {
            assert_eq!(batch.src.len(), 2 * 8);
            assert_eq!(batch.tgt.len(), 2 * 6);
            assert_eq!(batch.tgt_mask.len(), 2 * 6);
        }
        // Last batch has 1 real row.
        assert_eq!(batches[1].1, 1);
    }

    #[test]
    fn all_examples_appear_each_epoch() {
        let data: Vec<EncodedPair> = (0..10).map(|i| pair(4, i)).collect();
        let b = Batcher::new(data.clone(), 3, 8, 6);
        let mut rng = Rng::new(1);
        let batches = b.epoch(&mut rng);
        // Collect unique rows by first src token (tags distinct mod 10 here).
        let mut seen = std::collections::HashSet::new();
        for (batch, real) in &batches {
            for r in 0..*real {
                seen.insert(batch.src[r * 8]);
            }
        }
        assert_eq!(seen.len(), 10 - 6 + 6); // tags 0..10 → first tokens 4..14 mod wrap: 10 distinct? 4+(0+tag)%10 distinct for tag 0..10 → values 4..13 → 10
    }

    #[test]
    fn mask_counts_match_target_lengths() {
        let data = vec![pair(3, 0)];
        let b = Batcher::new(data, 1, 4, 8);
        let mut rng = Rng::new(2);
        let (batch, _) = &b.epoch(&mut rng)[0];
        // tgt = BOS + 3 tokens + EOS = 5 real → 4 live loss positions.
        let live: f32 = batch.tgt_mask.iter().sum();
        assert_eq!(live, 4.0);
    }

    #[test]
    fn bucketing_reduces_length_spread() {
        let mut data = Vec::new();
        for i in 0..64 {
            data.push(pair(2 + (i % 16), i));
        }
        let b = Batcher::new(data, 8, 20, 6);
        let mut rng = Rng::new(3);
        let batches = b.epoch(&mut rng);
        // Within a batch, src lengths (detected via first PAD position) should
        // be close after bucketing: check average in-batch spread is small.
        let mut spread_sum = 0usize;
        for (batch, real) in &batches {
            let mut lens = Vec::new();
            for r in 0..*real {
                let row = &batch.src[r * 20..(r + 1) * 20];
                let len = row.iter().position(|&x| x == 0).unwrap_or(20);
                lens.push(len);
            }
            spread_sum += lens.iter().max().unwrap() - lens.iter().min().unwrap();
        }
        let avg = spread_sum as f64 / batches.len() as f64;
        assert!(avg <= 4.0, "avg in-batch length spread {avg}");
    }

    #[test]
    fn qa_batcher_spans_inclusive() {
        let data = vec![EncodedQa { context: (4..20).collect(), question: vec![5, 6], span: (3, 5) }];
        let qb = QaBatcher::new(data, 2, 16, 4);
        let batches = qb.eval_batches();
        assert_eq!(batches.len(), 1);
        let (batch, real) = &batches[0];
        assert_eq!(*real, 1);
        assert_eq!(batch.start[0], 3);
        assert_eq!(batch.end[0], 4); // inclusive
        assert_eq!(batch.batch_size, 2); // padded by repetition
        assert_eq!(batch.context.len(), 2 * 16);
    }
}
