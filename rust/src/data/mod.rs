//! Data pipeline: encoded datasets, padded batches, background prefetching.

mod batcher;
mod loader;

pub use batcher::{Batch, Batcher, QaBatch, QaBatcher};
pub use loader::Prefetcher;

use crate::corpus::{QaExample, SeqPair};
use crate::text::Vocab;

/// A sequence-to-sequence example encoded to ids (BOS/EOS wrapped target).
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedPair {
    pub src: Vec<usize>,
    /// Target with BOS prefix and EOS suffix (teacher forcing layout).
    pub tgt: Vec<usize>,
}

/// A QA example encoded to ids.
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedQa {
    pub context: Vec<usize>,
    pub question: Vec<usize>,
    pub span: (usize, usize),
}

/// Encode seq2seq pairs with (possibly distinct) vocabularies.
pub fn encode_pairs(pairs: &[SeqPair], src_vocab: &Vocab, tgt_vocab: &Vocab) -> Vec<EncodedPair> {
    pairs
        .iter()
        .map(|p| EncodedPair {
            src: src_vocab.encode(&p.src),
            tgt: tgt_vocab.encode_wrapped(&p.tgt),
        })
        .collect()
}

/// Encode QA examples with a single shared vocabulary.
pub fn encode_qa(examples: &[QaExample], vocab: &Vocab) -> Vec<EncodedQa> {
    examples
        .iter()
        .map(|e| EncodedQa {
            context: vocab.encode(&e.context),
            question: vocab.encode(&e.question),
            span: e.span,
        })
        .collect()
}

/// Truncate sequences to maximum lengths (keeps spans valid by construction:
/// QA contexts are truncated only if the span fits, else the example drops).
pub fn truncate_pairs(pairs: &mut Vec<EncodedPair>, max_src: usize, max_tgt: usize) {
    for p in pairs.iter_mut() {
        p.src.truncate(max_src);
        if p.tgt.len() > max_tgt {
            p.tgt.truncate(max_tgt);
            // ensure EOS terminates the truncated target
            *p.tgt.last_mut().unwrap() = crate::text::EOS;
        }
    }
}

/// Drop QA examples whose span exceeds `max_ctx` after truncation.
pub fn truncate_qa(examples: &mut Vec<EncodedQa>, max_ctx: usize, max_q: usize) {
    examples.retain(|e| e.span.1 <= max_ctx);
    for e in examples.iter_mut() {
        e.context.truncate(max_ctx);
        e.question.truncate(max_q);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::{BOS, EOS};

    fn mini_vocab() -> Vocab {
        let data: Vec<Vec<String>> =
            vec![["a", "b", "c", "d"].iter().map(|s| s.to_string()).collect()];
        let refs: Vec<&[String]> = data.iter().map(|v| v.as_slice()).collect();
        Vocab::build(refs.iter().copied(), 100, 1)
    }

    #[test]
    fn encode_wraps_target() {
        let v = mini_vocab();
        let pairs = vec![SeqPair {
            src: vec!["a".into(), "b".into()],
            tgt: vec!["c".into()],
        }];
        let enc = encode_pairs(&pairs, &v, &v);
        assert_eq!(enc[0].src.len(), 2);
        assert_eq!(enc[0].tgt[0], BOS);
        assert_eq!(*enc[0].tgt.last().unwrap(), EOS);
    }

    #[test]
    fn truncation_preserves_eos() {
        let v = mini_vocab();
        let pairs = vec![SeqPair {
            src: (0..10).map(|_| "a".to_string()).collect(),
            tgt: (0..10).map(|_| "b".to_string()).collect(),
        }];
        let mut enc = encode_pairs(&pairs, &v, &v);
        truncate_pairs(&mut enc, 4, 5);
        assert_eq!(enc[0].src.len(), 4);
        assert_eq!(enc[0].tgt.len(), 5);
        assert_eq!(*enc[0].tgt.last().unwrap(), EOS);
    }

    #[test]
    fn qa_truncation_drops_unreachable_spans() {
        let mut ex = vec![
            EncodedQa { context: (0..20).collect(), question: vec![1], span: (18, 19) },
            EncodedQa { context: (0..20).collect(), question: vec![1], span: (2, 3) },
        ];
        truncate_qa(&mut ex, 10, 5);
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].span, (2, 3));
        assert_eq!(ex[0].context.len(), 10);
    }
}
