//! Wire-level conformance across network drivers.
//!
//! The `[net]` driver toggle must be invisible on the wire: every byte the
//! blocking thread-per-connection driver sends, the epoll reactor must send
//! too, for the full text + binary protocol surface — including when the
//! client fragments its requests one byte at a time, and when it pipelines
//! many binary frames into a single write. These tests drive real servers
//! (OS-assigned ports) under both drivers and compare raw response bytes.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;
use word2ket::config::{EmbeddingKind, ExperimentConfig, NetDriver};
use word2ket::coordinator::server::{accept_loop, spawn, ServerState};
use word2ket::serving::wire;

const DRIVERS: [NetDriver; 2] = [NetDriver::Threads, NetDriver::Epoll];

fn cfg_for(driver: NetDriver) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.embedding.kind = EmbeddingKind::Word2KetXS;
    cfg.embedding.order = 2;
    cfg.embedding.rank = 2;
    cfg.model.vocab = 100;
    cfg.model.emb_dim = 16;
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.serving.batch_window_us = 100;
    cfg.serving.shards = 2;
    cfg.serving.cache_rows = 64;
    cfg.net.driver = driver;
    cfg
}

fn start(driver: NetDriver) -> (Arc<ServerState>, String, JoinHandle<()>) {
    let (state, listener, addr) = spawn(&cfg_for(driver)).unwrap();
    let st = state.clone();
    let acc = std::thread::spawn(move || accept_loop(listener, st));
    (state, addr, acc)
}

/// Write `bytes` in one shot, read until the server closes.
fn roundtrip_batched(addr: &str, bytes: &[u8]) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).ok();
    s.write_all(bytes).unwrap();
    let mut out = Vec::new();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.read_to_end(&mut out).unwrap();
    out
}

/// Dribble `bytes` one at a time with small pauses so the server sees the
/// request fragmented across many reads (frames and lines split anywhere,
/// including mid-header and mid-f32), then read until close.
fn roundtrip_dribbled(addr: &str, bytes: &[u8]) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).ok();
    for (i, b) in bytes.iter().enumerate() {
        s.write_all(std::slice::from_ref(b)).unwrap();
        // Pause often enough that coalescing cannot reassemble everything,
        // without making the test crawl.
        if i % 3 == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let mut out = Vec::new();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.read_to_end(&mut out).unwrap();
    out
}

// -- request builders (hand-rolled: the test must not share encoder code
// with the client under test) ----------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn ids_frame(op: u32, ids: &[u32]) -> Vec<u8> {
    let mut f = Vec::new();
    put_u32(&mut f, op);
    put_u32(&mut f, ids.len() as u32);
    for &id in ids {
        put_u32(&mut f, id);
    }
    f
}

fn knn_vec_frame(query: &[f32], k: u32) -> Vec<u8> {
    let mut f = Vec::new();
    put_u32(&mut f, wire::OP_KNN_VEC);
    put_u32(&mut f, query.len() as u32);
    put_u32(&mut f, k);
    for &x in query {
        f.extend_from_slice(&x.to_le_bytes());
    }
    f
}

/// The deterministic text script: every command family, success and error
/// paths, empty lines, ending in QUIT (which closes without a reply).
/// STATS is deliberately absent — latency percentiles are timing-dependent
/// and would never be byte-identical across runs.
fn text_script() -> Vec<u8> {
    concat!(
        "PING\n",
        "PING extra\n",
        "\n",
        "LOOKUP 1 2 1\n",
        "LOOKUP\n",
        "LOOKUP abc\n",
        "LOOKUP 5000\n",
        "DOT 1 2\n",
        "DOT 1\n",
        "DOT a b\n",
        "KNN 42 5\n",
        "KNN 1 0\n",
        "KNN\n",
        "RELOAD\n",
        "NONSENSE then args\n",
        "QUIT\n",
    )
    .as_bytes()
    .to_vec()
}

/// The deterministic binary script: hello, then a pipeline of frames
/// covering every op (success and error), written as one blob. The server
/// must answer strictly in order; QUIT closes silently.
fn binary_script() -> Vec<u8> {
    let mut blob = Vec::new();
    blob.extend_from_slice(&wire::MAGIC);
    blob.extend_from_slice(&ids_frame(wire::OP_LOOKUP, &[1, 2, 1]));
    blob.extend_from_slice(&ids_frame(wire::OP_DOT, &[1, 2]));
    blob.extend_from_slice(&ids_frame(wire::OP_PING, &[]));
    blob.extend_from_slice(&ids_frame(wire::OP_PING, &[7])); // bad request
    blob.extend_from_slice(&ids_frame(wire::OP_KNN, &[42, 5]));
    blob.extend_from_slice(&ids_frame(wire::OP_KNN, &[1, 0])); // bad frame, survives
    blob.extend_from_slice(&ids_frame(wire::OP_LOOKUP, &[5000])); // range error
    blob.extend_from_slice(&ids_frame(wire::OP_LOOKUP, &[])); // empty: bad frame
    blob.extend_from_slice(&ids_frame(99, &[1])); // unknown op
    let query = [0.25f32; 16];
    blob.extend_from_slice(&knn_vec_frame(&query, 4));
    blob.extend_from_slice(&knn_vec_frame(&query, 0)); // bad request
    blob.extend_from_slice(&ids_frame(wire::OP_QUIT, &[]));
    blob
}

#[test]
fn text_surface_byte_identical_across_drivers_and_fragmentation() {
    let script = text_script();
    let mut per_driver = Vec::new();
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let batched = roundtrip_batched(&addr, &script);
        let dribbled = roundtrip_dribbled(&addr, &script);
        assert_eq!(
            batched, dribbled,
            "{driver}: fragmented text must answer byte-identically"
        );
        assert!(!batched.is_empty());
        // Spot-check shape: 3 rows for the triple lookup, errors as ERR.
        let text = String::from_utf8(batched.clone()).unwrap();
        assert_eq!(text.matches("OK 16 ").count(), 3, "{driver}: {text}");
        assert!(text.contains("ERR bad id\n"), "{driver}");
        assert!(text.contains("ERR unknown command\n"), "{driver}");
        per_driver.push(batched);
        state.shutdown();
        acc.join().unwrap();
    }
    assert_eq!(
        per_driver[0], per_driver[1],
        "threads and epoll drivers must answer the text protocol byte-identically"
    );
}

#[test]
fn binary_pipeline_byte_identical_across_drivers_and_fragmentation() {
    let script = binary_script();
    let mut per_driver = Vec::new();
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let batched = roundtrip_batched(&addr, &script);
        let dribbled = roundtrip_dribbled(&addr, &script);
        assert_eq!(
            batched, dribbled,
            "{driver}: fragmented binary frames must answer byte-identically"
        );
        // Hello first: MAGIC + dim 16.
        assert_eq!(&batched[..4], &wire::MAGIC);
        assert_eq!(u32::from_le_bytes(batched[4..8].try_into().unwrap()), 16);
        // First pipelined response: OK + 3 rows of 16 f32s, answered
        // strictly before the later frames' replies.
        assert_eq!(
            u32::from_le_bytes(batched[8..12].try_into().unwrap()),
            wire::STATUS_OK
        );
        assert_eq!(u32::from_le_bytes(batched[12..16].try_into().unwrap()), 3);
        per_driver.push(batched);
        state.shutdown();
        acc.join().unwrap();
    }
    assert_eq!(
        per_driver[0], per_driver[1],
        "threads and epoll drivers must answer the binary protocol byte-identically"
    );
}

#[test]
fn pipelined_frames_split_across_writes_mid_header() {
    // Split the pipelined blob at a frame-header boundary+2 bytes — the
    // parser must hold the partial header across reads under both drivers.
    let script = binary_script();
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_nodelay(true).ok();
        let cut = 4 + 8 + 2; // mid-header of the second frame's op word
        s.write_all(&script[..cut]).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        s.write_all(&script[cut..]).unwrap();
        let mut out = Vec::new();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.read_to_end(&mut out).unwrap();
        let whole = roundtrip_batched(&addr, &script);
        assert_eq!(out, whole, "{driver}: mid-header split changed the response bytes");
        state.shutdown();
        acc.join().unwrap();
    }
}

#[test]
fn hostile_count_header_errors_and_closes_under_both_drivers() {
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&wire::MAGIC).unwrap();
        let mut hello = [0u8; 8];
        s.read_exact(&mut hello).unwrap();
        let mut frame = Vec::new();
        put_u32(&mut frame, wire::OP_LOOKUP);
        put_u32(&mut frame, u32::MAX);
        s.write_all(&frame).unwrap();
        let mut resp = [0u8; 8];
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.read_exact(&mut resp).unwrap();
        assert_eq!(
            u32::from_le_bytes(resp[..4].try_into().unwrap()),
            wire::STATUS_BAD_FRAME,
            "{driver}"
        );
        let mut probe = [0u8; 1];
        assert_eq!(s.read(&mut probe).unwrap(), 0, "{driver}: conn must close");
        state.shutdown();
        acc.join().unwrap();
    }
}

#[test]
fn bad_magic_is_rejected_under_both_drivers() {
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let mut s = TcpStream::connect(&addr).unwrap();
        // First byte matches MAGIC[0], the rest does not.
        s.write_all(&[wire::MAGIC[0], b'X', b'Y', b'Z']).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        r.read_line(&mut line).unwrap();
        assert_eq!(line, "ERR bad magic\n", "{driver}");
        state.shutdown();
        acc.join().unwrap();
    }
}

#[test]
fn graceful_shutdown_drains_and_joins_under_both_drivers() {
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        // Park idle connections on both protocols; none sends QUIT.
        let mut idle_text = TcpStream::connect(&addr).unwrap();
        idle_text.write_all(b"PING\n").unwrap();
        let mut line = [0u8; 3];
        idle_text.read_exact(&mut line).unwrap();
        assert_eq!(&line, b"OK\n", "{driver}");
        let mut idle_bin = TcpStream::connect(&addr).unwrap();
        idle_bin.write_all(&wire::MAGIC).unwrap();
        let mut hello = [0u8; 8];
        idle_bin.read_exact(&mut hello).unwrap();

        state.shutdown();
        acc.join().unwrap_or_else(|_| panic!("{driver}: accept loop did not join"));

        // Parked clients observe EOF/reset, never a hang.
        for s in [&mut idle_text, &mut idle_bin] {
            s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            let mut probe = [0u8; 1];
            match s.read(&mut probe) {
                Ok(0) | Err(_) => {}
                Ok(n) => panic!("{driver}: expected EOF after shutdown, read {n}"),
            }
        }
    }
}

#[cfg(unix)]
#[test]
fn idle_timeout_reaps_parked_conns_under_epoll() {
    let mut cfg = cfg_for(NetDriver::Epoll);
    cfg.net.idle_timeout_ms = 300;
    let (state, listener, addr) = spawn(&cfg).unwrap();
    let st = state.clone();
    let acc = std::thread::spawn(move || accept_loop(listener, st));

    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"PING\n").unwrap();
    let mut line = [0u8; 3];
    s.read_exact(&mut line).unwrap();
    // Sit idle: the timer wheel must close the connection, well before the
    // generous read timeout below.
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let start = std::time::Instant::now();
    let mut probe = [0u8; 1];
    match s.read(&mut probe) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("expected idle close, read {n} bytes"),
    }
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "idle reap took {:?}",
        start.elapsed()
    );

    state.shutdown();
    acc.join().unwrap();
}

#[test]
fn metrics_exposition_byte_identical_across_drivers() {
    // Histogram samples are timing-dependent, so byte-identity is asserted
    // with the metrics plane disabled ([obs] enable = false): every family
    // still renders (all-zero), making the full exposition deterministic.
    // On each server the text verb and OP_METRICS must also agree byte for
    // byte.
    let mut per_driver = Vec::new();
    for driver in DRIVERS {
        let mut cfg = cfg_for(driver);
        cfg.obs.enable = false;
        let (state, listener, addr) = spawn(&cfg).unwrap();
        let st = state.clone();
        let acc = std::thread::spawn(move || accept_loop(listener, st));

        let text = roundtrip_batched(&addr, b"METRICS\nQUIT\n");
        let mut bin = word2ket::serving::BinaryClient::connect(&addr).unwrap();
        let bin_text = bin.metrics().unwrap();
        bin.quit().unwrap();
        assert_eq!(
            String::from_utf8(text.clone()).unwrap(),
            bin_text,
            "{driver}: text METRICS vs OP_METRICS diverge"
        );
        assert!(bin_text.ends_with("# EOF\n"), "{driver}: {bin_text}");
        per_driver.push(text);

        state.shutdown();
        acc.join().unwrap();
    }
    assert_eq!(
        per_driver[0], per_driver[1],
        "threads and epoll drivers must render METRICS byte-identically"
    );
}

#[test]
fn metrics_name_sets_match_across_drivers_under_traffic() {
    // With the plane enabled and live traffic, values differ but the
    // rendered families and their label sets must not depend on the driver.
    let mut names_per_driver: Vec<Vec<String>> = Vec::new();
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let mut bin = word2ket::serving::BinaryClient::connect(&addr).unwrap();
        bin.lookup(&[1, 2, 3]).unwrap();
        bin.knn(7, 4).unwrap();
        let text = bin.metrics().unwrap();
        bin.quit().unwrap();
        assert!(text.contains("w2k_served_total"), "{driver}: {text}");
        let names: Vec<String> = text
            .lines()
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .map(|l| l.split_whitespace().next().unwrap().to_string())
            .collect();
        names_per_driver.push(names);
        state.shutdown();
        acc.join().unwrap();
    }
    assert_eq!(
        names_per_driver[0], names_per_driver[1],
        "metric name/label sets diverge across drivers"
    );
}

/// Hand-rolled frame carrying the optional trace-context extension: the
/// high bit of the op word flags 24 extra bytes (u128 trace id + u64
/// parent span id, both little-endian) between the header and the payload.
fn traced_ids_frame(op: u32, ids: &[u32], trace_id: u128, parent_span: u64) -> Vec<u8> {
    let mut f = Vec::new();
    put_u32(&mut f, op | 0x8000_0000);
    put_u32(&mut f, ids.len() as u32);
    f.extend_from_slice(&trace_id.to_le_bytes());
    f.extend_from_slice(&parent_span.to_le_bytes());
    for &id in ids {
        put_u32(&mut f, id);
    }
    f
}

#[test]
fn tracing_config_never_changes_response_bytes() {
    // The acceptance bar for the trace plane's wire footprint: a server
    // head-sampling *every* request must answer the full binary script
    // byte-identically to one with the tracer disabled outright — responses
    // never carry trace bytes; the extension exists on requests only.
    let script = binary_script();
    for driver in DRIVERS {
        let mut responses = Vec::new();
        for (sample, ring) in [(0.0, 0), (1.0, 64)] {
            let mut cfg = cfg_for(driver);
            cfg.obs.trace_sample = sample;
            cfg.obs.trace_ring_len = ring;
            let (state, listener, addr) = spawn(&cfg).unwrap();
            let st = state.clone();
            let acc = std::thread::spawn(move || accept_loop(listener, st));
            responses.push(roundtrip_batched(&addr, &script));
            state.shutdown();
            acc.join().unwrap();
        }
        assert_eq!(
            responses[0], responses[1],
            "{driver}: sampling every request changed the response bytes"
        );
    }
}

#[test]
fn traced_frames_answer_byte_identically_to_untraced() {
    // A client stamping the trace-context extension onto its frames must
    // get the exact bytes an untraced client gets, under both drivers,
    // batched and dribbled one byte at a time (the 24 extension bytes
    // fragment across reads like any other frame bytes).
    let trace_id = 0xfeed_f00d_dead_beef_0123_4567_89ab_cdefu128;
    let mut traced = Vec::new();
    traced.extend_from_slice(&wire::MAGIC);
    traced.extend_from_slice(&traced_ids_frame(wire::OP_LOOKUP, &[1, 2, 1], trace_id, 7));
    traced.extend_from_slice(&traced_ids_frame(wire::OP_KNN, &[42, 5], trace_id, 7));
    traced.extend_from_slice(&ids_frame(wire::OP_QUIT, &[]));
    let mut untraced = Vec::new();
    untraced.extend_from_slice(&wire::MAGIC);
    untraced.extend_from_slice(&ids_frame(wire::OP_LOOKUP, &[1, 2, 1]));
    untraced.extend_from_slice(&ids_frame(wire::OP_KNN, &[42, 5]));
    untraced.extend_from_slice(&ids_frame(wire::OP_QUIT, &[]));
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let plain = roundtrip_batched(&addr, &untraced);
        let batched = roundtrip_batched(&addr, &traced);
        let dribbled = roundtrip_dribbled(&addr, &traced);
        assert_eq!(batched, plain, "{driver}: trace extension leaked into the response");
        assert_eq!(batched, dribbled, "{driver}: fragmented traced frames diverged");
        state.shutdown();
        acc.join().unwrap();
    }
    // A hostile count with the trace flag set must die at the header, before
    // the server ever reads the extension bytes.
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&wire::MAGIC).unwrap();
        let mut hello = [0u8; 8];
        s.read_exact(&mut hello).unwrap();
        let mut frame = Vec::new();
        put_u32(&mut frame, wire::OP_LOOKUP | 0x8000_0000);
        put_u32(&mut frame, u32::MAX);
        s.write_all(&frame).unwrap();
        let mut resp = [0u8; 8];
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        s.read_exact(&mut resp).unwrap();
        assert_eq!(
            u32::from_le_bytes(resp[..4].try_into().unwrap()),
            wire::STATUS_BAD_FRAME,
            "{driver}"
        );
        state.shutdown();
        acc.join().unwrap();
    }
}

#[test]
fn op_trace_returns_the_span_tree_for_a_propagated_context() {
    // The default config arms the trace ring (64 entries) even with
    // head-sampling off, so a propagated context is always honored: send a
    // traced LOOKUP with a client-chosen trace id, then fetch the stored
    // span over OP_TRACE and check the per-stage breakdown.
    let trace_id = 0x0123_4567_89ab_cdef_feed_f00d_dead_beefu128;
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let mut bin = word2ket::serving::BinaryClient::connect(&addr).unwrap();
        let ctx = word2ket::obs::TraceContext { trace_id, span_id: 0x5afe };
        let rows = bin.lookup_traced(&[1, 2, 3], Some(ctx)).unwrap();
        assert_eq!(rows.len(), 3, "{driver}");
        let text = bin.trace(trace_id).unwrap();
        let hex = word2ket::obs::TraceContext::hex(trace_id);
        assert!(
            text.contains(&format!("trace=\"{hex}\"")),
            "{driver}: trace id missing from dump: {text}"
        );
        assert!(text.contains("w2k_trace_span"), "{driver}: {text}");
        // The propagated span id is the stored span's parent.
        assert!(
            text.contains("parent=\"0000000000005afe\""),
            "{driver}: propagated context not honored as parent: {text}"
        );
        assert!(
            text.contains("stage=\"batch_wait\""),
            "{driver}: per-stage breakdown missing: {text}"
        );
        assert!(text.ends_with("# EOF\n"), "{driver}: {text}");
        // An unknown id answers an empty (EOF-only) dump, not an error.
        let empty = bin.trace(0x1).unwrap();
        assert!(!empty.contains("w2k_trace_span"), "{driver}: {empty}");
        assert!(empty.ends_with("# EOF\n"), "{driver}: {empty}");
        bin.quit().unwrap();
        state.shutdown();
        acc.join().unwrap();
    }
}

#[test]
fn stats_views_consistent_under_both_drivers() {
    for driver in DRIVERS {
        let (state, addr, acc) = start(driver);
        let mut bin = word2ket::serving::BinaryClient::connect(&addr).unwrap();
        bin.lookup(&[1, 2, 3]).unwrap();
        bin.knn(7, 4).unwrap();
        let binary = bin.stats().unwrap();
        assert!(binary.served > 0, "{driver}");
        assert_eq!(binary.accept_errors, 0, "{driver}");
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"STATS\n").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut text = String::new();
        r.read_line(&mut text).unwrap();
        assert!(text.contains("accept_errors=0"), "{driver}: {text}");
        s.write_all(b"QUIT\n").ok();
        bin.quit().unwrap();
        state.shutdown();
        acc.join().unwrap();
    }
}
