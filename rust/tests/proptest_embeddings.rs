//! Property-based tests over the embedding stores (paper eq. 3 / eq. 4
//! semantics, parameter accounting, baselines' structural bounds).

use word2ket::embedding::{
    materialize, EmbeddingStore, LowRankEmbedding, QuantizedEmbedding, RegularEmbedding,
    Word2Ket, Word2KetXS,
};
use word2ket::prop_assert;
use word2ket::testing::{check, close};
use word2ket::util::ceil_root;

#[test]
fn prop_xs_param_formula() {
    check("word2ketXS params = r·n·q·t (eq. 4)", |c| {
        let vocab = c.dim(4, 2000);
        let dim = c.dim(4, 300);
        let order = c.dim(2, 4);
        let rank = c.dim(1, 8);
        let e = Word2KetXS::random(vocab, dim, order, rank, &mut c.rng);
        let q = ceil_root(dim, order as u32).max(2);
        let t = ceil_root(vocab, order as u32).max(2);
        prop_assert!(
            e.num_params() == rank * order * q * t,
            "got {} want {}",
            e.num_params(),
            rank * order * q * t
        );
        Ok(())
    });
}

#[test]
fn prop_xs_capacity_covers_vocab() {
    check("t^n >= d (vocabulary coverage)", |c| {
        let vocab = c.dim(2, 5000);
        let order = c.dim(2, 4);
        let e = Word2KetXS::random(vocab, 16, order, 1, &mut c.rng);
        prop_assert!(
            e.leaf_t().pow(order as u32) >= vocab,
            "t^n = {} < vocab {vocab}",
            e.leaf_t().pow(order as u32)
        );
        // Every word id must be addressable.
        let last = e.lookup(vocab - 1);
        prop_assert!(last.len() == 16, "bad dim");
        Ok(())
    });
}

#[test]
fn prop_lookup_batch_consistent() {
    check("lookup_batch rows == lookup (all stores)", |c| {
        let vocab = c.dim(8, 200);
        let dim = c.dim(4, 32);
        let stores: Vec<Box<dyn EmbeddingStore>> = vec![
            Box::new(RegularEmbedding::random(vocab, dim, &mut c.rng)),
            Box::new(Word2Ket::random(vocab, dim, 2, 2, &mut c.rng)),
            Box::new(Word2KetXS::random(vocab, dim, 2, 3, &mut c.rng)),
            Box::new(QuantizedEmbedding::random(vocab, dim, 8, &mut c.rng)),
            Box::new(LowRankEmbedding::random(vocab, dim, 4, &mut c.rng)),
        ];
        let ids: Vec<usize> = (0..5).map(|_| c.rng.below(vocab)).collect();
        for s in &stores {
            let batch = s.lookup_batch(&ids);
            for (row, &id) in ids.iter().enumerate() {
                let single = s.lookup(id);
                for (a, b) in batch.row(row).iter().zip(single.iter()) {
                    close(*a, *b, 1e-6)?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_xs_matches_materialized() {
    check("XS lazy row == materialized row", |c| {
        let vocab = c.dim(4, 64);
        let dim = c.dim(4, 32);
        let e = Word2KetXS::random(vocab, dim, 2, c.dim(1, 4), &mut c.rng);
        let m = materialize(&e);
        let id = c.rng.below(vocab);
        let lazy = e.lookup(id);
        for (a, b) in m.row(id).iter().zip(lazy.iter()) {
            close(*a, *b, 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_quantization_error_bound() {
    check("per-row quantization error ≤ scale/2", |c| {
        let vocab = c.dim(2, 30);
        let dim = c.dim(4, 64);
        let bits = [2usize, 4, 8][c.rng.below(3)];
        let a = (3.0 / dim as f32).sqrt();
        let dense = c.vec_f32(vocab * dim, -a, a);
        let q = QuantizedEmbedding::from_dense(vocab, dim, &dense, bits);
        let row = c.rng.below(vocab);
        let rec = q.lookup(row);
        let bound = q.max_row_error(row) + 1e-6;
        for col in 0..dim {
            let err = (rec[col] - dense[row * dim + col]).abs();
            prop_assert!(err <= bound, "err {err} > bound {bound} (bits {bits})");
        }
        Ok(())
    });
}

#[test]
fn prop_saving_rates_ordering() {
    check("XS saving beats w2k beats regular at same (order, rank)", |c| {
        let vocab = c.dim(100, 5000);
        let dim = c.dim(16, 128);
        let order = c.dim(2, 3);
        let rank = c.dim(1, 3);
        let w2k = Word2Ket::random(vocab, dim, order, rank, &mut c.rng);
        let xs = Word2KetXS::random(vocab, dim, order, rank, &mut c.rng);
        prop_assert!(
            xs.num_params() < w2k.num_params(),
            "XS {} !< w2k {}",
            xs.num_params(),
            w2k.num_params()
        );
        // word2ket compresses exactly when r·n·q < p (paper regime: small
        // rank, q = ⌈p^{1/n}⌉ ≪ p); the inequality is conditional, not
        // universal — assert the condition itself.
        let q = w2k.leaf_dim();
        if rank * order * q < dim {
            prop_assert!(
                w2k.num_params() < vocab * dim,
                "w2k {} !< regular {}",
                w2k.num_params(),
                vocab * dim
            );
        }
        Ok(())
    });
}

#[test]
fn prop_w2k_layernorm_finite() {
    check("w2k reconstruction finite with LN on/off", |c| {
        let vocab = c.dim(2, 30);
        let dim = c.dim(4, 80);
        let order = c.dim(2, 4);
        let mut e = Word2Ket::random(vocab, dim, order, c.dim(1, 4), &mut c.rng);
        for ln in [false, true] {
            e.set_layernorm(ln);
            let v = e.lookup(c.rng.below(vocab));
            prop_assert!(v.iter().all(|x| x.is_finite()), "non-finite with ln={ln}");
            prop_assert!(v.len() == dim, "dim {} != {dim}", v.len());
        }
        Ok(())
    });
}
