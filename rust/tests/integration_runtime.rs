//! Integration tests over the full runtime: PJRT execution of AOT artifacts,
//! kernel-vs-Rust-oracle agreement, training-loss descent, checkpoint
//! resume, greedy decode, and QA prediction.
//!
//! These tests need `make artifacts` to have run; they are skipped (with a
//! loud message) when artifacts/ is missing so `cargo test` stays green on a
//! fresh clone.

use std::path::Path;
use std::rc::Rc;
use word2ket::config::{EmbeddingKind, ExperimentConfig, TaskKind};
use word2ket::coordinator::experiment::{resolve_variant, run_with};
use word2ket::coordinator::schedule::LrSchedule;
use word2ket::coordinator::tasks::{prepare_qa, prepare_seq2seq};
use word2ket::coordinator::trainer::{greedy_decode, predict_spans, Trainer};
use word2ket::kron::kron_vec;
use word2ket::runtime::{Engine, Manifest, ParamStore, Value};
use word2ket::util::Rng;

// The xla client is !Send/!Sync (Rc internals), so each test thread holds
// its own engine via a thread-local.
fn runtime() -> Option<Rc<(Engine, Manifest)>> {
    thread_local! {
        static RT: std::cell::RefCell<Option<Option<Rc<(Engine, Manifest)>>>> =
            const { std::cell::RefCell::new(None) };
    }
    RT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let dir = Path::new("artifacts");
            *slot = Some(if dir.join("manifest.json").exists() {
                let engine = Engine::cpu(dir).expect("engine");
                let manifest = Manifest::load(dir).expect("manifest");
                Some(Rc::new((engine, manifest)))
            } else {
                eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
                None
            });
        }
        slot.as_ref().unwrap().clone()
    })
}

fn tiny_cfg(task: TaskKind, kind: EmbeddingKind, order: usize, rank: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.task = task;
    cfg.embedding.kind = kind;
    cfg.embedding.order = order;
    cfg.embedding.rank = rank;
    cfg.train.steps = 6;
    cfg.train.eval_every = 0;
    cfg.train.warmup = 0;
    cfg.train.lr = 3e-3;
    cfg.corpus.train = 64;
    cfg.corpus.valid = 8;
    cfg.corpus.test = 8;
    cfg
}

// ---------------------------------------------------------------------------
// Kernel artifacts vs pure-Rust oracles
// ---------------------------------------------------------------------------

#[test]
fn kernel_kron_pair_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let k = &manifest.kernels["kernel_kron_pair"];
    let mut rng = Rng::new(11);
    let a: Vec<f32> = rng.uniform_vec(16 * 8, -1.0, 1.0);
    let b: Vec<f32> = rng.uniform_vec(16 * 8, -1.0, 1.0);
    let out = engine
        .run(
            &k.file,
            &[
                Value::F32(a.clone(), vec![16, 8]),
                Value::F32(b.clone(), vec![16, 8]),
            ],
        )
        .expect("run kron_pair");
    let got = out[0].as_f32().unwrap();
    for row in 0..16 {
        let expect = kron_vec(&a[row * 8..(row + 1) * 8], &b[row * 8..(row + 1) * 8]);
        for (i, e) in expect.iter().enumerate() {
            let g = got[row * 64 + i];
            assert!((g - e).abs() < 1e-5, "row {row} idx {i}: {g} vs {e}");
        }
    }
}

#[test]
fn kernel_xs_rows_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let k = &manifest.kernels["kernel_xs_rows"];
    let mut rng = Rng::new(12);
    // (16, 2, 2, 8): batch 16, rank 2, order 2, q 8.
    let cols: Vec<f32> = rng.uniform_vec(16 * 2 * 2 * 8, -1.0, 1.0);
    let out = engine
        .run(&k.file, &[Value::F32(cols.clone(), vec![16, 2, 2, 8])])
        .expect("run xs_rows");
    let got = out[0].as_f32().unwrap();
    for b in 0..16 {
        let mut expect = vec![0.0f32; 64];
        for r in 0..2 {
            let off = ((b * 2) + r) * 2 * 8;
            let term = kron_vec(&cols[off..off + 8], &cols[off + 8..off + 16]);
            for i in 0..64 {
                expect[i] += term[i];
            }
        }
        for i in 0..64 {
            let g = got[b * 64 + i];
            assert!((g - expect[i]).abs() < 1e-4, "b {b} i {i}: {g} vs {}", expect[i]);
        }
    }
}

#[test]
fn kernel_layernorm_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let k = &manifest.kernels["kernel_layernorm"];
    let mut rng = Rng::new(13);
    let x: Vec<f32> = rng.uniform_vec(16 * 64, -2.0, 2.0);
    let out = engine
        .run(&k.file, &[Value::F32(x.clone(), vec![16, 64])])
        .expect("run layernorm");
    let got = out[0].as_f32().unwrap();
    let expect = word2ket::tensor::layernorm_slices(&x, 64).unwrap();
    for i in 0..x.len() {
        assert!((got[i] - expect[i]).abs() < 1e-4, "idx {i}: {} vs {}", got[i], expect[i]);
    }
}

#[test]
fn kernel_attention_probs_sum_to_one() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let k = &manifest.kernels["kernel_attention"];
    let mut rng = Rng::new(14);
    let h: Vec<f32> = rng.uniform_vec(16 * 64, -1.0, 1.0);
    let enc: Vec<f32> = rng.uniform_vec(16 * 24 * 64, -1.0, 1.0);
    // Mask: first 10 positions valid.
    let mut mask = vec![0.0f32; 16 * 24];
    for b in 0..16 {
        for t in 0..10 {
            mask[b * 24 + t] = 1.0;
        }
    }
    let out = engine
        .run(
            &k.file,
            &[
                Value::F32(h, vec![16, 64]),
                Value::F32(enc, vec![16, 24, 64]),
                Value::F32(mask, vec![16, 24]),
            ],
        )
        .expect("run attention");
    let probs = out[1].as_f32().unwrap();
    for b in 0..16 {
        let row = &probs[b * 24..(b + 1) * 24];
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "batch {b}: prob sum {sum}");
        for t in 10..24 {
            assert!(row[t].abs() < 1e-6, "masked position {t} has prob {}", row[t]);
        }
    }
}

// ---------------------------------------------------------------------------
// Training behaviour
// ---------------------------------------------------------------------------

#[test]
fn seq2seq_loss_decreases() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let cfg = tiny_cfg(TaskKind::Summarization, EmbeddingKind::Regular, 1, 1);
    let variant = resolve_variant(&cfg, manifest).unwrap();
    let data = prepare_seq2seq(&cfg, variant).unwrap();
    let mut store = ParamStore::init(&variant.params, 1);
    let mut trainer = Trainer::new(engine, variant, LrSchedule::new(5e-3, 0));
    let mut rng = Rng::new(2);
    let batches = data.train.epoch(&mut rng);
    let mut losses = Vec::new();
    for (batch, _) in batches.iter().take(8).cycle().take(12) {
        losses.push(trainer.step_seq2seq(&mut store, batch).unwrap());
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
    // First loss ≈ ln(vocab): uniform predictions.
    let v = variant.dims["vocab"] as f32;
    assert!((losses[0] - v.ln()).abs() < 1.0, "initial loss {} vs ln(V) {}", losses[0], v.ln());
}

#[test]
fn qa_loss_decreases_all_variants() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    for (kind, order, rank) in [
        (EmbeddingKind::Regular, 1, 1),
        (EmbeddingKind::Word2KetXS, 2, 2),
        (EmbeddingKind::Word2KetXS, 4, 1),
    ] {
        let cfg = tiny_cfg(TaskKind::Qa, kind, order, rank);
        let variant = resolve_variant(&cfg, manifest).unwrap();
        let data = prepare_qa(&cfg, variant).unwrap();
        let mut store = ParamStore::init(&variant.params, 1);
        let mut trainer = Trainer::new(engine, variant, LrSchedule::new(5e-3, 0));
        let mut rng = Rng::new(3);
        let batches = data.train.epoch(&mut rng);
        let mut losses = Vec::new();
        for (batch, _) in batches.iter().cycle().take(10) {
            losses.push(trainer.step_qa(&mut store, batch).unwrap());
        }
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "{kind:?} {order}/{rank}: loss did not decrease: {losses:?}"
        );
    }
}

#[test]
fn greedy_decode_emits_valid_tokens() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let cfg = tiny_cfg(TaskKind::Summarization, EmbeddingKind::Word2KetXS, 2, 10);
    let variant = resolve_variant(&cfg, manifest).unwrap();
    let data = prepare_seq2seq(&cfg, variant).unwrap();
    let store = ParamStore::init(&variant.params, 1);
    let (batch, _) = &data.test.eval_batches()[0];
    let seqs = greedy_decode(engine, variant, &store, batch, 8).unwrap();
    assert_eq!(seqs.len(), batch.batch_size);
    let vocab = variant.dims["vocab"];
    for s in &seqs {
        assert!(s.len() <= 8);
        assert!(s.iter().all(|&t| t < vocab), "token out of vocab: {s:?}");
    }
}

#[test]
fn qa_predict_spans_in_range() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let cfg = tiny_cfg(TaskKind::Qa, EmbeddingKind::Regular, 1, 1);
    let variant = resolve_variant(&cfg, manifest).unwrap();
    let data = prepare_qa(&cfg, variant).unwrap();
    let store = ParamStore::init(&variant.params, 5);
    let (batch, _) = &data.test.eval_batches()[0];
    let spans = predict_spans(engine, variant, &store, batch).unwrap();
    let ctx_len = variant.dims["ctx_len"];
    let max_ans = variant.dims["max_answer_len"];
    for &(s, e) in &spans {
        assert!(s < ctx_len && e < ctx_len, "span ({s},{e}) out of range");
        assert!(e >= s, "end before start");
        assert!(e - s < max_ans, "span longer than max_answer_len");
    }
}

#[test]
fn checkpoint_resume_continues_exactly() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let cfg = tiny_cfg(TaskKind::Qa, EmbeddingKind::Word2KetXS, 2, 2);
    let variant = resolve_variant(&cfg, manifest).unwrap();
    let data = prepare_qa(&cfg, variant).unwrap();
    let mut rng = Rng::new(4);
    let batches = data.train.epoch(&mut rng);

    // Path A: 4 straight steps.
    let mut store_a = ParamStore::init(&variant.params, 9);
    let mut tr_a = Trainer::new(engine, variant, LrSchedule::new(3e-3, 0));
    for (batch, _) in batches.iter().take(4) {
        tr_a.step_qa(&mut store_a, batch).unwrap();
    }

    // Path B: 2 steps, checkpoint, reload, 2 more steps.
    let dir = std::env::temp_dir().join("w2k_resume_test");
    let path = dir.join("resume.ckpt");
    let mut store_b = ParamStore::init(&variant.params, 9);
    let mut tr_b = Trainer::new(engine, variant, LrSchedule::new(3e-3, 0));
    for (batch, _) in batches.iter().take(2) {
        tr_b.step_qa(&mut store_b, batch).unwrap();
    }
    store_b.save(&path).unwrap();
    let mut store_b2 = ParamStore::load(&variant.params, &path).unwrap();
    assert_eq!(store_b2.step, 2);
    let mut tr_b2 = Trainer::new(engine, variant, LrSchedule::new(3e-3, 0));
    for (batch, _) in batches.iter().skip(2).take(2) {
        tr_b2.step_qa(&mut store_b2, batch).unwrap();
    }

    // Final losses must match to float tolerance.
    let la = *tr_a.losses.last().unwrap();
    let lb = *tr_b2.losses.last().unwrap();
    assert!(
        (la - lb).abs() < 1e-5,
        "resume diverged: straight {la} vs resumed {lb}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn full_experiment_smoke_mt() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let mut cfg = tiny_cfg(TaskKind::Translation, EmbeddingKind::Word2KetXS, 3, 10);
    cfg.train.steps = 4;
    let variant = resolve_variant(&cfg, manifest).unwrap();
    let mut store = ParamStore::init(&variant.params, 1);
    let report = run_with(&cfg, engine, variant, &mut store, false).unwrap();
    assert_eq!(report.steps, 4);
    assert!(report.final_metrics.iter().any(|(k, _)| k == "BLEU"));
    assert!(report.step_time_mean_ms > 0.0);
}

#[test]
fn manifest_files_all_present() {
    let Some(rt) = runtime() else { return };
    let manifest = &rt.1;
    let reg = word2ket::runtime::ArtifactRegistry::open(Path::new("artifacts")).unwrap();
    assert!(reg.missing_files().is_empty(), "missing: {:?}", reg.missing_files());
    assert!(manifest.variants.len() >= 11, "expected all 11 variants");
    assert!(manifest.kernels.len() >= 4, "expected 4 kernel artifacts");
}

#[test]
fn beam_width1_matches_greedy() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let cfg = tiny_cfg(TaskKind::Summarization, EmbeddingKind::Regular, 1, 1);
    let variant = resolve_variant(&cfg, manifest).unwrap();
    let data = prepare_seq2seq(&cfg, variant).unwrap();
    let store = ParamStore::init(&variant.params, 3);
    let (batch, _) = &data.test.eval_batches()[0];
    let greedy = greedy_decode(engine, variant, &store, batch, 6).unwrap();
    let beam1 =
        word2ket::coordinator::beam::beam_decode(engine, variant, &store, batch, 6, 1).unwrap();
    assert_eq!(greedy, beam1, "beam width 1 must equal greedy");
}

#[test]
fn beam_width3_scores_at_least_greedy() {
    let Some(rt) = runtime() else { return };
    let (engine, manifest) = (&rt.0, &rt.1);
    let cfg = tiny_cfg(TaskKind::Summarization, EmbeddingKind::Word2KetXS, 2, 10);
    let variant = resolve_variant(&cfg, manifest).unwrap();
    let data = prepare_seq2seq(&cfg, variant).unwrap();
    // brief training so the distribution is non-degenerate
    let mut store = ParamStore::init(&variant.params, 4);
    let mut trainer = Trainer::new(engine, variant, LrSchedule::new(5e-3, 0));
    let mut rng = Rng::new(5);
    for (batch, _) in data.train.epoch(&mut rng).iter().take(6) {
        trainer.step_seq2seq(&mut store, batch).unwrap();
    }
    let (batch, _) = &data.test.eval_batches()[0];
    let beams =
        word2ket::coordinator::beam::beam_decode(engine, variant, &store, batch, 8, 3).unwrap();
    assert_eq!(beams.len(), batch.batch_size);
    let vocab = variant.dims["vocab"];
    for s in &beams {
        assert!(s.iter().all(|&t| t < vocab && t != word2ket::text::EOS));
        assert!(s.len() <= 8);
    }
}
