//! Property-based tests for the Kronecker/CP algebra — the invariants the
//! paper's math rests on (§2.1–§2.3, §3.1–§3.2).

use word2ket::kron::{kron_chain, kron_entry, kron_mat, kron_row, kron_tree, CpTensor, MixedRadix};
use word2ket::prop_assert;
use word2ket::tensor::Tensor;
use word2ket::testing::{check, close};

#[test]
fn prop_kron_bilinearity() {
    check("kron bilinearity", |c| {
        let n = c.dim(2, 6);
        let m = c.dim(2, 6);
        let u = c.vec_f32(n, -2.0, 2.0);
        let v = c.vec_f32(n, -2.0, 2.0);
        let w = c.vec_f32(m, -2.0, 2.0);
        let alpha = c.rng.uniform(-2.0, 2.0);
        // (u + αv) ⊗ w == u⊗w + α(v⊗w)
        let lhs_in: Vec<f32> = u.iter().zip(&v).map(|(a, b)| a + alpha * b).collect();
        let lhs = word2ket::kron::kron_vec(&lhs_in, &w);
        let uw = word2ket::kron::kron_vec(&u, &w);
        let vw = word2ket::kron::kron_vec(&v, &w);
        for i in 0..lhs.len() {
            close(lhs[i], uw[i] + alpha * vw[i], 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_tree_equals_chain() {
    check("balanced tree == chain (associativity)", |c| {
        let order = c.dim(1, 5);
        let q = c.dim(2, 5);
        let leaves: Vec<Vec<f32>> = (0..order).map(|_| c.vec_f32(q, -1.0, 1.0)).collect();
        let refs: Vec<&[f32]> = leaves.iter().map(|v| v.as_slice()).collect();
        let a = kron_chain(&refs);
        let b = kron_tree(&refs);
        prop_assert!(a.len() == b.len(), "length mismatch");
        for i in 0..a.len() {
            close(a[i], b[i], 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_norm_multiplicativity() {
    check("‖v⊗w‖ = ‖v‖·‖w‖ (§2.1)", |c| {
        let lv = c.dim(1, 12);
        let lw = c.dim(1, 12);
        let v = c.vec_f32(lv, -3.0, 3.0);
        let w = c.vec_f32(lw, -3.0, 3.0);
        let vw = word2ket::kron::kron_vec(&v, &w);
        let nv = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nw = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nvw = vw.iter().map(|x| x * x).sum::<f32>().sqrt();
        close(nvw, nv * nw, 1e-3)
    });
}

#[test]
fn prop_mixed_radix_roundtrip() {
    check("mixed-radix encode∘decode = id", |c| {
        let ndig = c.dim(1, 5);
        let radices: Vec<usize> = (0..ndig).map(|_| c.dim(2, 9)).collect();
        let r = MixedRadix::new(radices);
        let i = c.rng.below(r.capacity());
        prop_assert!(r.encode(&r.decode(i)) == i, "roundtrip failed at {i}");
        Ok(())
    });
}

#[test]
fn prop_lazy_entry_matches_dense() {
    check("lazy (A⊗B)_{ij} identity (§3.2)", |c| {
        let (m, n) = (c.dim(1, 4), c.dim(1, 4));
        let (p, q) = (c.dim(1, 4), c.dim(1, 4));
        let a = Tensor::new(vec![m, n], c.vec_f32(m * n, -1.0, 1.0)).unwrap();
        let b = Tensor::new(vec![p, q], c.vec_f32(p * q, -1.0, 1.0)).unwrap();
        let dense = kron_mat(&a, &b);
        let i = c.rng.below(m * p);
        let j = c.rng.below(n * q);
        close(kron_entry(&[&a, &b], i, j), dense.at2(i, j), 1e-4)
    });
}

#[test]
fn prop_lazy_row_matches_dense() {
    check("lazy row reconstruction (§3.2)", |c| {
        let (m, n) = (c.dim(2, 4), c.dim(1, 4));
        let (p, q) = (c.dim(2, 4), c.dim(1, 4));
        let a = Tensor::new(vec![m, n], c.vec_f32(m * n, -1.0, 1.0)).unwrap();
        let b = Tensor::new(vec![p, q], c.vec_f32(p * q, -1.0, 1.0)).unwrap();
        let dense = kron_mat(&a, &b);
        let i = c.rng.below(m * p);
        let lazy = kron_row(&[&a, &b], i);
        for j in 0..lazy.len() {
            close(lazy[j], dense.at2(i, j), 1e-4)?;
        }
        Ok(())
    });
}

#[test]
fn prop_factored_inner_product() {
    check("factored ⟨v,w⟩ == dense (§2.3)", |c| {
        let order = c.dim(2, 4);
        let q = c.dim(2, 4);
        let r1 = c.dim(1, 4);
        let r2 = c.dim(1, 4);
        let mut ra = c.rng.fork(1);
        let mut rb = c.rng.fork(2);
        let a = CpTensor::random(r1, order, q, &mut ra);
        let b = CpTensor::random(r2, order, q, &mut rb);
        let dense: f32 = a
            .reconstruct()
            .iter()
            .zip(b.reconstruct().iter())
            .map(|(x, y)| x * y)
            .sum();
        close(a.inner(&b), dense, 5e-3)
    });
}

#[test]
fn prop_cp_param_count() {
    check("CP storage is r·n·q (eq. 3)", |c| {
        let r = c.dim(1, 6);
        let n = c.dim(1, 5);
        let q = c.dim(2, 6);
        let t = CpTensor::zeros(r, n, q);
        prop_assert!(t.num_params() == r * n * q, "params {}", t.num_params());
        prop_assert!(t.dim() == q.pow(n as u32), "dim {}", t.dim());
        Ok(())
    });
}
