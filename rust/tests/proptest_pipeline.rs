//! Property-based tests over the NLP substrate: metrics bounds, batcher
//! invariants, vocabulary handling, config/checkpoint roundtrips.

use word2ket::config::{EmbeddingKind, ExperimentConfig};
use word2ket::corpus::{self};
use word2ket::data::{encode_pairs, Batcher, EncodedPair};
use word2ket::metrics::{corpus_bleu, qa_f1, rouge_l, rouge_n};
use word2ket::prop_assert;
use word2ket::testing::check;
use word2ket::text::{Vocab, BOS, EOS, PAD};

fn rand_tokens(c: &mut word2ket::testing::Cases, len: usize, alphabet: usize) -> Vec<String> {
    (0..len)
        .map(|_| format!("w{}", c.rng.below(alphabet.max(1))))
        .collect()
}

#[test]
fn prop_metric_ranges() {
    check("ROUGE/BLEU/F1 ∈ [0,1]; identity ⇒ 1", |c| {
        let la = c.dim(1, 12);
        let lb = c.dim(1, 12);
        let a = rand_tokens(c, la, 8);
        let b = rand_tokens(c, lb, 8);
        for s in [rouge_n(&a, &b, 1).f1, rouge_n(&a, &b, 2).f1, rouge_l(&a, &b).f1, qa_f1(&a, &b)] {
            prop_assert!((0.0..=1.0 + 1e-9).contains(&s), "score {s} out of range");
        }
        prop_assert!((rouge_l(&a, &a).f1 - 1.0).abs() < 1e-9, "identity rouge != 1");
        prop_assert!((qa_f1(&a, &a) - 1.0).abs() < 1e-9, "identity f1 != 1");
        let bleu = corpus_bleu(&[(a.clone(), a.clone())]);
        prop_assert!((bleu.bleu - 100.0).abs() < 1e-6, "identity bleu {}", bleu.bleu);
        Ok(())
    });
}

#[test]
fn prop_rouge_symmetric_f1() {
    check("ROUGE-N F1 symmetric under swap", |c| {
        let la = c.dim(1, 10);
        let lb = c.dim(1, 10);
        let a = rand_tokens(c, la, 6);
        let b = rand_tokens(c, lb, 6);
        let ab = rouge_n(&a, &b, 1).f1;
        let ba = rouge_n(&b, &a, 1).f1;
        prop_assert!((ab - ba).abs() < 1e-9, "{ab} vs {ba}");
        Ok(())
    });
}

#[test]
fn prop_batcher_preserves_examples() {
    check("every example appears exactly once per epoch", |c| {
        let n = c.dim(1, 40);
        let batch = c.dim(1, 8);
        // Tag each example with a unique first token.
        let data: Vec<EncodedPair> = (0..n)
            .map(|i| EncodedPair {
                src: vec![4 + i, 4, 5],
                tgt: vec![BOS, 4 + i, EOS],
            })
            .collect();
        let b = Batcher::new(data, batch, 8, 5);
        let mut rng = c.rng.fork(0);
        let mut seen = std::collections::HashMap::new();
        for (bt, real) in b.epoch(&mut rng) {
            for r in 0..real {
                *seen.entry(bt.src[r * 8]).or_insert(0usize) += 1;
            }
        }
        prop_assert!(seen.len() == n, "saw {} of {n}", seen.len());
        prop_assert!(seen.values().all(|&v| v == 1), "duplicates: {seen:?}");
        Ok(())
    });
}

#[test]
fn prop_batcher_padding_is_pad() {
    check("padding beyond seq length is PAD", |c| {
        let len = c.dim(1, 6);
        let data = vec![EncodedPair {
            src: (0..len).map(|i| 4 + i).collect(),
            tgt: vec![BOS, 4, EOS],
        }];
        let b = Batcher::new(data, 2, 10, 6);
        let mut rng = c.rng.fork(0);
        let (bt, _) = &b.epoch(&mut rng)[0];
        for r in 0..2 {
            for t in len..10 {
                prop_assert!(
                    bt.src[r * 10 + t] == PAD as i64,
                    "non-PAD at ({r},{t}): {}",
                    bt.src[r * 10 + t]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_vocab_encode_decode() {
    check("vocab decode(encode(x)) == x for in-vocab tokens", |c| {
        let lt = c.dim(1, 30);
        let toks = rand_tokens(c, lt, 10);
        let refs: Vec<&[String]> = vec![toks.as_slice()];
        let v = Vocab::build(refs.into_iter(), 1000, 1);
        let ids = v.encode_wrapped(&toks);
        prop_assert!(ids[0] == BOS && *ids.last().unwrap() == EOS, "missing wrap");
        let back = v.decode(&ids);
        prop_assert!(back == toks, "roundtrip failed");
        Ok(())
    });
}

#[test]
fn prop_corpus_generators_deterministic() {
    check("corpus generation deterministic in seed", |c| {
        let mut cfg = ExperimentConfig::default().corpus;
        cfg.seed = c.rng.next_u64();
        cfg.train = 5;
        cfg.valid = 2;
        cfg.test = 2;
        let a = corpus::summarization::generate(&cfg, 300);
        let b = corpus::summarization::generate(&cfg, 300);
        prop_assert!(a.train == b.train, "summarization not deterministic");
        let a = corpus::qa::generate(&cfg, 300);
        let b = corpus::qa::generate(&cfg, 300);
        prop_assert!(a.train == b.train, "qa not deterministic");
        let a = corpus::translation::generate(&cfg, 300);
        let b = corpus::translation::generate(&cfg, 300);
        prop_assert!(a.train == b.train, "translation not deterministic");
        Ok(())
    });
}

#[test]
fn prop_qa_spans_valid_after_encode() {
    check("encoded QA spans index real tokens", |c| {
        let mut cfg = ExperimentConfig::default().corpus;
        cfg.seed = c.rng.next_u64();
        cfg.train = 8;
        cfg.valid = 0;
        cfg.test = 0;
        let splits = corpus::qa::generate(&cfg, 400);
        for ex in &splits.train {
            prop_assert!(ex.span.1 <= ex.context.len(), "span escapes context");
            prop_assert!(!ex.answers.is_empty(), "no answers");
            prop_assert!(
                ex.answer_tokens() == ex.answers[0].as_slice(),
                "span/answer disagree"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_config_override_roundtrip() {
    check("config override → typed value", |c| {
        let steps = c.dim(1, 10_000);
        let cfg = word2ket::config::load_with_overrides(
            None,
            &[
                format!("train.steps={steps}"),
                "embedding.kind=word2ketxs".to_string(),
                "embedding.order=2".to_string(),
            ],
        )
        .map_err(|e| e.to_string())?;
        prop_assert!(cfg.train.steps == steps, "steps {}", cfg.train.steps);
        prop_assert!(cfg.embedding.kind == EmbeddingKind::Word2KetXS, "kind");
        Ok(())
    });
}

#[test]
fn prop_translation_source_is_function_of_target() {
    check("same target ⇒ same source rendering", |c| {
        let seed = c.rng.next_u64();
        let lt = c.dim(2, 8);
        let tgt = rand_tokens(c, lt, 6);
        let a = corpus::translation::to_source(&tgt, seed);
        let b = corpus::translation::to_source(&tgt, seed);
        prop_assert!(a == b, "not deterministic");
        Ok(())
    });
}
