//! End-to-end cluster acceptance tests: real shard servers on loopback,
//! scatter-gather routing, replica failover, and rolling snapshot reload.
//!
//! Shard servers here are completely stock single-node servers
//! (`coordinator::server`) booted from per-shard snapshot files — exactly
//! what `w2k serve --set snapshot.path=shardN.snap` runs in production.

use word2ket::cluster::{
    save_shard_snapshots, shard_snapshot_path, Router, RouterConfig, ShardStrategy, Topology,
};
use word2ket::config::{ExperimentConfig, NetConfig, NetDriver};
use word2ket::coordinator::server::{self, ServerState};
use word2ket::embedding::{EmbeddingStore, RegularEmbedding};
use word2ket::index::{BruteForce, Query, Scorer};
use word2ket::serving::{wire, BinaryClient};
use word2ket::snapshot::SaveOptions;
use word2ket::util::Rng;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One live shard server (state + bound address + accept thread).
struct Node {
    state: Arc<ServerState>,
    addr: String,
    accept: std::thread::JoinHandle<()>,
}

impl Node {
    fn kill(self) {
        self.state.shutdown();
        self.accept.join().expect("accept loop");
    }
}

/// The `[net]` config every server and router in this file runs under. The
/// CI matrix re-runs the whole suite per driver by exporting
/// `W2K_NET_DRIVER=threads|epoll`; locally, unset means the default
/// (threads). An unknown value is a test bug — fail loudly.
fn net_from_env() -> NetConfig {
    let mut net = NetConfig::default();
    if let Ok(name) = std::env::var("W2K_NET_DRIVER") {
        net.driver = NetDriver::parse(&name).expect("bad W2K_NET_DRIVER");
    }
    net
}

fn spawn_node(snap: &Path) -> Node {
    let mut cfg = ExperimentConfig::default();
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.serving.batch_window_us = 50;
    cfg.serving.shards = 2;
    cfg.serving.cache_rows = 512;
    cfg.snapshot.path = snap.display().to_string();
    cfg.net = net_from_env();
    let (state, listener, addr) = server::spawn(&cfg).expect("shard server");
    let st = state.clone();
    let accept = std::thread::spawn(move || server::accept_loop(listener, st));
    Node { state, addr, accept }
}

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("w2k_cluster_e2e_{}_{name}", std::process::id()))
}

fn router_cfg() -> RouterConfig {
    RouterConfig {
        connect_timeout: Duration::from_millis(500),
        io_timeout: Duration::from_millis(5000),
        probe_interval: Duration::from_millis(50),
        eject_after: 2,
        net: net_from_env(),
        ..RouterConfig::default()
    }
}

/// A live cluster: per-shard snapshot files, one stock server per replica,
/// and a topology whose addresses are the actually-bound ports.
struct Cluster {
    nodes: Vec<Vec<Node>>,
    topo: Topology,
    dir: PathBuf,
}

impl Cluster {
    fn start(
        store: &dyn EmbeddingStore,
        strategy: ShardStrategy,
        shards: usize,
        replicas: usize,
        name: &str,
    ) -> Cluster {
        let placeholder = (0..shards).map(|_| vec!["127.0.0.1:0".to_string()]).collect();
        let topo = Topology::new(store.vocab_size(), strategy, placeholder).unwrap();
        let dir = tmp_dir(name);
        let saved = save_shard_snapshots(store, &topo, &dir, &SaveOptions::default()).unwrap();
        let mut nodes = Vec::with_capacity(shards);
        let mut addrs = Vec::with_capacity(shards);
        for (path, _) in &saved {
            let group: Vec<Node> = (0..replicas).map(|_| spawn_node(path)).collect();
            addrs.push(group.iter().map(|n| n.addr.clone()).collect());
            nodes.push(group);
        }
        let topo = topo.with_addrs(addrs).unwrap();
        Cluster { nodes, topo, dir }
    }

    fn stop(self) {
        for group in self.nodes {
            for node in group {
                node.kill();
            }
        }
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn regular_store(vocab: usize, dim: usize, seed: u64) -> Arc<RegularEmbedding> {
    let mut rng = Rng::new(seed);
    Arc::new(RegularEmbedding::random(vocab, dim, &mut rng))
}

/// Acceptance: scatter-gather KNN over 2-shard and 4-shard splits (both
/// strategies) is bit-identical — ids *and* scores — to the single-node
/// BruteForce answer on the same store, including k larger than the
/// vocabulary and a wire-level comparison against a real single-node
/// server.
#[test]
fn scatter_gather_knn_bit_identical_to_single_node() {
    let store = regular_store(211, 16, 7);
    let dyn_store: Arc<dyn EmbeddingStore> = store.clone();
    let truth = BruteForce::new(Scorer::new(dyn_store, false));

    for (shards, strategy) in
        [(2, ShardStrategy::Range), (4, ShardStrategy::Range), (2, ShardStrategy::Hash)]
    {
        let name = format!("knn_{}_{}", shards, strategy.name());
        let cluster = Cluster::start(store.as_ref(), strategy, shards, 1, &name);
        let router = Router::new(cluster.topo.clone(), router_cfg());

        for &q in &[0usize, 17, 105, 210] {
            for &k in &[1usize, 5, 23, 500] {
                let (want, _) = truth.top_k(&Query::Id(q), k);
                let got = router.knn(q as u32, k as u32).unwrap();
                assert_eq!(
                    got.len(),
                    want.len(),
                    "{shards} shards {strategy:?}: q={q} k={k} length"
                );
                for (g, w) in got.iter().zip(want.iter()) {
                    assert!(
                        g.0 as usize == w.id && g.1 == w.score,
                        "{shards} shards {strategy:?}: q={q} k={k}: {g:?} vs {w:?}"
                    );
                }
            }
        }
        router.shutdown();
        cluster.stop();
    }

    // Wire-to-wire: the router's answer equals a real single-node server's
    // answer over the same snapshot bits.
    let dir = tmp_dir("knn_single");
    std::fs::create_dir_all(&dir).unwrap();
    let full = dir.join("full.snap");
    word2ket::snapshot::save_store(store.as_ref(), &full, &SaveOptions::default()).unwrap();
    let single = spawn_node(&full);
    let mut client = BinaryClient::connect(&single.addr).unwrap();

    let cluster = Cluster::start(store.as_ref(), ShardStrategy::Range, 4, 1, "knn_wire");
    let router = Router::new(cluster.topo.clone(), router_cfg());
    for &(q, k) in &[(3u32, 7u32), (150, 12)] {
        let want = client.knn(q, k).unwrap();
        let got = router.knn(q, k).unwrap();
        assert_eq!(got, want, "router vs single-node server for q={q} k={k}");
    }
    client.quit().unwrap();
    single.kill();
    router.shutdown();
    cluster.stop();
    std::fs::remove_dir_all(&dir).ok();
}

/// Lookups reassemble in request order across shards (duplicates included),
/// DOT co-routes or crosses shards correctly, and the STATS roll-up sees
/// the traffic.
#[test]
fn lookup_dot_and_stats_across_shards() {
    let store = regular_store(101, 8, 11);
    let cluster = Cluster::start(store.as_ref(), ShardStrategy::Range, 3, 1, "lookup");
    let router = Router::new(cluster.topo.clone(), router_cfg());
    assert_eq!(router.dim().unwrap(), 8);

    // Ids deliberately out of shard order, with repeats.
    let ids = [100u32, 0, 55, 0, 33, 99, 1, 55];
    let rows = router.lookup(&ids).unwrap();
    assert_eq!(rows.len(), ids.len());
    for (row, &gid) in rows.iter().zip(&ids) {
        assert_eq!(row, &store.lookup(gid as usize), "row for global id {gid}");
    }

    // DOT: same-shard pair (co-routed) and cross-shard pair (router-side).
    for &(a, b) in &[(1u32, 2u32), (0, 100)] {
        let want = word2ket::tensor::dot(&store.lookup(a as usize), &store.lookup(b as usize));
        assert_eq!(router.dot(a, b).unwrap(), want, "dot({a},{b})");
    }

    let cs = router.stats();
    assert_eq!(cs.total_replicas, 3);
    assert_eq!(cs.healthy_replicas, 3);
    assert!(cs.aggregate.served > 0, "roll-up must see the lookups");
    assert_eq!(cs.min_generation, 1);
    assert_eq!(cs.max_generation, 1);
    assert!(cs.replicas.iter().all(|r| r.stats.is_some()));

    router.shutdown();
    cluster.stop();
}

/// Acceptance for the quantized serving path end to end: shard snapshots
/// saved with the int4 codec boot completely stock servers that serve the
/// f16-refined quantized-ket rows, scatter-gather KNN scores those rows
/// exactly (the router broadcasts the query *vector*, so shards score
/// materialized rows, never the coarse codes), and the STATS/METRICS
/// roll-ups surface the sub-byte payload. The CI matrix re-runs this per
/// net driver like every other test in this file.
#[test]
fn quantized_snapshot_cluster_serves_refined_rows() {
    use word2ket::embedding::Word2Ket;
    use word2ket::quant::QuantizedKet;
    use word2ket::snapshot::Codec;
    use word2ket::tensor::dot;

    let mut rng = Rng::new(43);
    let w2k = Word2Ket::random(96, 16, 2, 2, &mut rng);
    // The exact store the servers will serve: the same conversion
    // `save_store` performs for a sub-byte codec.
    let qk = QuantizedKet::from_word2ket(&w2k, 4).unwrap();
    let rows: Vec<Vec<f32>> = (0..96).map(|id| qk.lookup(id)).collect();

    // `Cluster::start` saves at the default codec; this leg saves int4.
    let placeholder: Vec<Vec<String>> = (0..2).map(|_| vec!["127.0.0.1:0".to_string()]).collect();
    let topo = Topology::new(96, ShardStrategy::Range, placeholder).unwrap();
    let dir = tmp_dir("quantized");
    let opts = SaveOptions { codec: Codec::Int4, ..SaveOptions::default() };
    let saved = save_shard_snapshots(&w2k, &topo, &dir, &opts).unwrap();
    let mut nodes = Vec::new();
    let mut addrs: Vec<Vec<String>> = Vec::new();
    for (path, _) in &saved {
        let node = spawn_node(path);
        addrs.push(vec![node.addr.clone()]);
        nodes.push(node);
    }
    let topo = topo.with_addrs(addrs).unwrap();
    let router = Router::new(topo, router_cfg());

    // LOOKUP serves the refined rows — not the original float rows.
    let ids = [0u32, 95, 48, 7];
    for (row, &gid) in router.lookup(&ids).unwrap().iter().zip(&ids) {
        assert_eq!(row, &rows[gid as usize], "refined row for global id {gid}");
        assert_ne!(row, &w2k.lookup(gid as usize), "row {gid} cannot be the float original");
    }

    // Scatter-gather KNN: ids *and* scores bit-identical to a dense scan
    // over the refined rows.
    for &(q, k) in &[(5usize, 4usize), (60, 9)] {
        let mut want: Vec<(usize, f32)> =
            (0..96).filter(|&b| b != q).map(|b| (b, dot(&rows[q], &rows[b]))).collect();
        want.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        want.truncate(k);
        let got = router.knn(q as u32, k as u32).unwrap();
        assert_eq!(got.len(), k);
        for (g, w) in got.iter().zip(&want) {
            assert!(g.0 as usize == w.0 && g.1 == w.1, "q={q} k={k}: {g:?} vs {w:?}");
        }
    }

    // The roll-up reports the sub-byte payload: STATS takes the maximum
    // across replicas, and the METRICS scrape re-emits each shard's gauge.
    let cs = router.stats();
    assert_eq!(cs.healthy_replicas, 2);
    assert_eq!(cs.aggregate.payload_bits, 4, "roll-up must surface the int4 payload");
    let rolled = router.metrics();
    for s in 0..2 {
        assert!(
            rolled.contains(&format!("w2k_payload_bits{{shard=\"{s}\",replica=\"0\"}} 4")),
            "{rolled}"
        );
    }

    router.shutdown();
    for node in nodes {
        node.kill();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Mixed lookup+knn load through the router; returns total successful
/// requests, panicking on any failure.
fn hammer(router: &Router, threads: usize, iters: usize, mid: impl FnOnce()) -> u64 {
    let stop_mid = AtomicBool::new(false);
    let total = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let router = router.clone();
                let stop_mid = &stop_mid;
                scope.spawn(move || -> u64 {
                    let vocab = router.topology().vocab() as u32;
                    let mut ok = 0u64;
                    for i in 0..iters {
                        if i == iters / 3 {
                            stop_mid.store(true, Ordering::SeqCst);
                        }
                        let base = (t * 31 + i) as u32;
                        let ids =
                            [base % vocab, (base * 7 + 3) % vocab, (base * 13 + 1) % vocab];
                        let rows = router
                            .lookup(&ids)
                            .expect("lookup failed during failover/reload");
                        assert_eq!(rows.len(), 3);
                        if i % 5 == 0 {
                            let ns = router
                                .knn(ids[0], 3)
                                .expect("knn failed during failover/reload");
                            assert!(!ns.is_empty());
                        }
                        ok += 1;
                    }
                    ok
                })
            })
            .collect();
        // Run the mid-load action once a third of the work is done (the
        // deadline only matters if a load thread panicked early — the
        // panic then surfaces at join instead of hanging the test).
        let deadline = Instant::now() + Duration::from_secs(60);
        while !stop_mid.load(Ordering::SeqCst) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        mid();
        handles.into_iter().map(|h| h.join().expect("load thread")).sum()
    });
    total
}

/// Acceptance: killing one replica mid-load yields zero failed client
/// requests — the router fails over to the surviving replica — and the
/// prober ejects a connection-dead replica.
#[test]
fn replica_failover_zero_failed_requests() {
    let store = regular_store(120, 8, 13);
    let cluster = Cluster::start(store.as_ref(), ShardStrategy::Range, 2, 2, "failover");
    let router = Router::new(cluster.topo.clone(), router_cfg());

    // Warm every pooled connection so the kill hits live state.
    router.lookup(&[0, 60, 119]).unwrap();

    let victim_state = cluster.nodes[0][0].state.clone();
    let total = hammer(&router, 4, 120, || victim_state.shutdown());
    assert_eq!(total, 4 * 120, "every request must succeed across the kill");

    router.shutdown();
    cluster.stop();
}

/// A replica whose address refuses connections is ejected by the probe
/// loop after `eject_after` consecutive failures, while every client
/// request keeps succeeding on the live replica.
#[test]
fn dead_replica_is_ejected_by_the_prober() {
    let store = regular_store(60, 8, 17);
    let cluster = Cluster::start(store.as_ref(), ShardStrategy::Range, 2, 1, "ejection");

    // Reserve a port, then free it: a deterministic connection-refused
    // address standing in as shard 0's second replica.
    let dead_addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let mut addrs: Vec<Vec<String>> =
        (0..2).map(|s| vec![cluster.topo.replicas(s)[0].clone()]).collect();
    addrs[0].push(dead_addr);
    let topo = cluster.topo.with_addrs(addrs).unwrap();
    let router = Router::new(topo, router_cfg());

    // Requests succeed from the start (failover off the dead replica).
    for i in 0..20u32 {
        assert_eq!(router.lookup(&[i % 60]).unwrap().len(), 1);
    }

    // The prober ejects the dead replica within a few probe periods.
    let deadline = Instant::now() + Duration::from_secs(5);
    while router.health().healthy_count() != 2 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(router.health().healthy_count(), 2, "dead replica not ejected");
    assert_eq!(router.health().total(), 3);
    let cs = router.stats();
    assert_eq!(cs.healthy_replicas, 2);

    // Still serving, straight to the healthy replica.
    assert_eq!(router.lookup(&[5]).unwrap()[0], store.lookup(5));

    router.shutdown();
    cluster.stop();
}

/// Acceptance: rolling reload under live load — every replica of every
/// shard steps to the new generation (verified via STATS), the server
/// never answers STATUS_RELOAD_FAILED, and no client request fails.
#[test]
fn rolling_reload_increments_every_replica_generation() {
    let store = regular_store(90, 8, 19);
    let cluster = Cluster::start(store.as_ref(), ShardStrategy::Range, 2, 2, "reload_v1");
    let router = Router::new(cluster.topo.clone(), router_cfg());

    // Generation-2 shard snapshots (same rows — a config-identical
    // redeploy) in a second directory.
    let dir2 = tmp_dir("reload_v2");
    save_shard_snapshots(store.as_ref(), &cluster.topo, &dir2, &SaveOptions::default())
        .unwrap();

    let dir2_for_mid = dir2.clone();
    let router_for_mid = router.clone();
    let total = hammer(&router, 4, 120, move || {
        let generations = router_for_mid
            .rolling_reload_dir(&dir2_for_mid)
            .expect("rolling reload must succeed");
        assert_eq!(generations, vec![2, 2]);
    });
    assert_eq!(total, 4 * 120, "every request must succeed across the rolling reload");

    // Every replica reports the new generation in its own STATS.
    let cs = router.stats();
    assert_eq!(cs.min_generation, 2);
    assert_eq!(cs.max_generation, 2);
    for r in &cs.replicas {
        assert_eq!(
            r.stats.as_ref().map(|s| s.model_generation),
            Some(2),
            "shard {} replica {} stuck on the old generation",
            r.shard,
            r.replica
        );
    }

    // Rows unchanged (same weights redeployed).
    assert_eq!(router.lookup(&[42]).unwrap()[0], store.lookup(42));

    // A rolling reload pointed at a missing directory fails cleanly and
    // leaves generations intact.
    assert!(router.rolling_reload_dir(Path::new("/nonexistent")).is_err());
    assert_eq!(router.stats().min_generation, 2);

    router.shutdown();
    std::fs::remove_dir_all(&dir2).ok();
    cluster.stop();
}

/// The router's own listener speaks both wire protocols: binary + text
/// LOOKUP/KNN/PING/STATS/RELOAD against a live 2-shard cluster, with the
/// STATS drift helper asserting the two protocol views stay in lockstep.
#[test]
fn router_listener_serves_both_protocols() {
    use std::io::{BufRead, BufReader, Write};

    let store = regular_store(80, 16, 23);
    let cluster = Cluster::start(store.as_ref(), ShardStrategy::Range, 2, 1, "listener_v1");
    let (state, listener, addr) =
        word2ket::cluster::server::spawn(cluster.topo.clone(), router_cfg(), "127.0.0.1:0")
            .unwrap();
    let st = state.clone();
    let accept = std::thread::spawn(move || word2ket::cluster::server::accept_loop(listener, st));

    // Binary protocol.
    let mut bin = BinaryClient::connect(&addr).unwrap();
    assert_eq!(bin.dim, 16);
    let rows = bin.lookup(&[0, 79, 40, 0]).unwrap();
    assert_eq!(rows.len(), 4);
    assert_eq!(rows[0], store.lookup(0));
    assert_eq!(rows[0], rows[3]);
    bin.ping().unwrap();
    let neighbors = bin.knn(11, 5).unwrap();
    assert_eq!(neighbors.len(), 5);
    assert!(neighbors.iter().all(|&(id, _)| id != 11));
    match bin.lookup(&[500]) {
        Err(word2ket::serving::WireError::Status(s)) => assert_eq!(s, wire::STATUS_RANGE),
        other => panic!("expected range error, got {other:?}"),
    }

    // Text protocol on the same listener.
    let mut text = std::net::TcpStream::connect(&addr).unwrap();
    let mut text_reader = BufReader::new(text.try_clone().unwrap());
    let mut line = String::new();
    let mut ask = |sock: &mut std::net::TcpStream,
                   reader: &mut BufReader<std::net::TcpStream>,
                   req: &str,
                   line: &mut String| {
        sock.write_all(req.as_bytes()).unwrap();
        line.clear();
        reader.read_line(line).unwrap();
        line.trim().to_string()
    };
    let resp = ask(&mut text, &mut text_reader, "PING\n", &mut line);
    assert_eq!(resp, "OK");
    let resp = ask(&mut text, &mut text_reader, "LOOKUP 7\n", &mut line);
    assert!(resp.starts_with("OK 16 "), "{resp}");
    let resp = ask(&mut text, &mut text_reader, "KNN 7 3\n", &mut line);
    assert!(resp.starts_with("OK 3 "), "{resp}");
    let resp = ask(&mut text, &mut text_reader, "NONSENSE\n", &mut line);
    assert!(resp.starts_with("ERR"), "{resp}");

    // Drift check across protocols, quiescent between the two fetches; the
    // cluster extras after the standard fields are tolerated.
    let text_stats = ask(&mut text, &mut text_reader, "STATS\n", &mut line);
    let bin_stats = bin.stats().unwrap();
    word2ket::testing::assert_stats_consistent(&text_stats, &bin_stats);
    assert!(text_stats.contains("healthy_replicas=2"), "{text_stats}");
    assert!(text_stats.contains("shards=2"), "{text_stats}");

    // Rolling RELOAD through the router's wire: new shard snapshots, text
    // form first (generation 2), then binary (generation 3).
    let dir2 = tmp_dir("listener_v2");
    save_shard_snapshots(store.as_ref(), &cluster.topo, &dir2, &SaveOptions::default())
        .unwrap();
    let resp =
        ask(&mut text, &mut text_reader, &format!("RELOAD {}\n", dir2.display()), &mut line);
    assert_eq!(resp, "OK generation=2", "{resp}");
    let generation = bin.reload(&dir2.display().to_string()).unwrap();
    assert_eq!(generation, 3);
    assert!(bin.reload("/nonexistent").is_err());
    // Shard files from generation 1 still exist — prove the canonical
    // naming the reload used matches the writer's.
    assert!(shard_snapshot_path(&dir2, 0).exists());
    assert!(shard_snapshot_path(&dir2, 1).exists());

    text.write_all(b"QUIT\n").ok();
    bin.quit().unwrap();
    state.shutdown();
    accept.join().unwrap();
    std::fs::remove_dir_all(&dir2).ok();
    cluster.stop();
}

/// CI smoke for the metrics plane end-to-end over a live 2-shard cluster:
/// each shard answers `OP_METRICS` with its own families, and the router's
/// roll-up re-emits every replica's samples with `shard`/`replica` labels
/// alongside its own router families and scrape markers.
#[test]
fn metrics_scrape_across_cluster() {
    let store = regular_store(64, 8, 31);
    let cluster = Cluster::start(store.as_ref(), ShardStrategy::Range, 2, 1, "metrics");
    let router = Router::new(cluster.topo.clone(), router_cfg());

    // Traffic through the router so shard and router counters move.
    let rows = router.lookup(&[0, 63, 1]).unwrap();
    assert_eq!(rows.len(), 3);
    router.knn(5, 3).unwrap();

    // Direct shard scrape over the binary wire.
    let mut shard_client = BinaryClient::connect(&cluster.topo.replicas(0)[0]).unwrap();
    let shard_text = shard_client.metrics().unwrap();
    shard_client.quit().unwrap();
    assert!(shard_text.contains("w2k_served_total"), "{shard_text}");
    assert!(
        shard_text.contains("w2k_stage_us_count{stage=\"batch_wait\"}"),
        "{shard_text}"
    );
    assert!(shard_text.ends_with("# EOF\n"), "{shard_text}");

    // Router roll-up: own families first, then per-replica sections.
    let rolled = router.metrics();
    assert!(
        rolled.contains("w2k_router_shard_failovers_total{shard=\"0\"} 0"),
        "{rolled}"
    );
    assert!(
        rolled.contains("w2k_router_shard_timeouts_total{shard=\"1\"} 0"),
        "{rolled}"
    );
    assert!(rolled.contains("w2k_router_healthy_replicas 2"), "{rolled}");
    assert!(rolled.contains("w2k_stage_us_count{stage=\"route\"}"), "{rolled}");
    for (s, r) in [(0, 0), (1, 0)] {
        assert!(
            rolled.contains(&format!("w2k_scrape_ok{{shard=\"{s}\",replica=\"{r}\"}} 1")),
            "shard {s} replica {r} scrape missing: {rolled}"
        );
        // Unbraced shard samples gain a label set; braced ones gain the
        // shard labels in front of their own.
        assert!(
            rolled.contains(&format!("w2k_served_total{{shard=\"{s}\",replica=\"{r}\"}}")),
            "{rolled}"
        );
        assert!(
            rolled.contains(&format!(
                "w2k_stage_us_count{{shard=\"{s}\",replica=\"{r}\",stage=\"kernel\"}}"
            )),
            "{rolled}"
        );
    }
    // The scraped servers' own terminators are dropped; exactly one EOF.
    assert!(rolled.ends_with("# EOF\n"), "{rolled}");
    assert_eq!(rolled.matches("# EOF").count(), 1, "{rolled}");

    router.shutdown();
    cluster.stop();
}

/// Pull one label value (`key="…"`) out of an exposition line.
fn label_value<'a>(line: &'a str, key: &str) -> &'a str {
    let pat = format!("{key}=\"");
    let start = line.find(&pat).unwrap_or_else(|| panic!("no {key} label in: {line}")) + pat.len();
    &line[start..start + line[start..].find('"').unwrap()]
}

/// The µs value after the closing brace of an exposition line.
fn sample_value(line: &str) -> u64 {
    line.rsplit(' ').next().unwrap().parse().unwrap_or_else(|_| panic!("bad sample: {line}"))
}

/// Acceptance for the trace plane: a head-sampled KNN through a 2-shard
/// router yields ONE assembled trace — the router's root span parenting a
/// span group from every shard — fetched with `Router::trace_text`, which
/// scrapes each shard's ring over the admin connections and relabels the
/// spans exactly like the METRICS roll-up. Killing a shard degrades the
/// assembly visibly (`w2k_trace_scrape_ok … 0`) instead of hiding it.
#[test]
fn assembled_trace_spans_the_cluster_and_degrades_visibly() {
    let store = regular_store(64, 8, 37);
    let mut cluster = Cluster::start(store.as_ref(), ShardStrategy::Range, 2, 1, "trace");
    let mut rc = router_cfg();
    // Sample every routed request; the stock shard servers keep their
    // default config (ring armed, no self-sampling) and record spans only
    // under the router's propagated context.
    rc.obs.trace_sample = 1.0;
    let router = Router::new(cluster.topo.clone(), rc);

    let neighbors = router.knn(5, 3).unwrap();
    assert_eq!(neighbors.len(), 3);

    // The router's own ring names the trace: the head-sampled root span.
    let ring = router.trace_slow_text();
    let root_line = ring
        .lines()
        .find(|l| l.contains("op=\"knn\"") && l.contains("parent=\"0000000000000000\""))
        .unwrap_or_else(|| panic!("no sampled knn root in ring: {ring}"));
    let trace_hex = label_value(root_line, "trace").to_string();
    let root_span = label_value(root_line, "span").to_string();
    let trace_id = word2ket::obs::TraceContext::parse_hex(&trace_hex).unwrap();

    let assembled = router.trace_text(trace_id);
    assert!(assembled.ends_with("# EOF\n"), "{assembled}");
    assert_eq!(assembled.matches("# EOF").count(), 1, "{assembled}");
    for s in 0..2 {
        assert!(
            assembled.contains(&format!("w2k_trace_scrape_ok{{shard=\"{s}\",replica=\"0\"}} 1")),
            "shard {s} scrape missing: {assembled}"
        );
    }

    // Router-side spans come first, unlabeled: the root and the query-row
    // lookup child it spawned before the scatter.
    assert!(
        assembled.contains(&format!("span=\"{root_span}\",parent=\"0000000000000000\"")),
        "{assembled}"
    );
    assert!(
        assembled
            .contains(&format!("parent=\"{root_span}\",op=\"lookup\"")),
        "query-row lookup child missing: {assembled}"
    );

    // Every shard contributes a KNN span parented directly under the
    // router's root — the cross-node tree the tentpole promises.
    let shard_spans: Vec<&str> = assembled
        .lines()
        .filter(|l| {
            l.starts_with("w2k_trace_span{shard=")
                && l.contains(&format!("parent=\"{root_span}\""))
        })
        .collect();
    assert!(shard_spans.len() >= 2, "root parents {} shard spans: {assembled}", shard_spans.len());
    for s in 0..2 {
        assert!(
            shard_spans.iter().any(|l| label_value(l, "shard") == s.to_string()),
            "no shard-{s} span under the root: {assembled}"
        );
    }

    // Per-shard stage accounting: each shard span's stage sum lands within
    // one log₂-histogram bucket width of the span's own duration (clock
    // reads truncate to µs, so a few-µs floor keeps sub-bucket spans honest).
    for line in assembled.lines().filter(|l| l.starts_with("w2k_trace_span{shard=")) {
        let span_hex = label_value(line, "span");
        let total = sample_value(line);
        let stage_sum: u64 = assembled
            .lines()
            .filter(|l| {
                l.starts_with("w2k_trace_stage{shard=")
                    && label_value(l, "span") == span_hex
            })
            .map(sample_value)
            .sum();
        let slack = word2ket::obs::bucket_width(total).max(32);
        assert!(
            total.abs_diff(stage_sum) <= slack,
            "span {span_hex}: stages sum to {stage_sum}µs vs {total}µs total \
             (slack {slack}µs): {assembled}"
        );
    }

    // Kill shard 1's only replica: the re-assembled dump must keep the
    // router spans and shard 0, and mark shard 1's scrape dead — a partial
    // trace that says so beats a silently complete-looking one.
    cluster.nodes[1].remove(0).kill();
    let degraded = router.trace_text(trace_id);
    assert!(
        degraded.contains("w2k_trace_scrape_ok{shard=\"1\",replica=\"0\"} 0"),
        "{degraded}"
    );
    assert!(
        degraded.contains("w2k_trace_scrape_ok{shard=\"0\",replica=\"0\"} 1"),
        "{degraded}"
    );
    assert!(
        degraded.contains(&format!("span=\"{root_span}\",parent=\"0000000000000000\"")),
        "{degraded}"
    );
    assert!(!degraded.contains("w2k_trace_span{shard=\"1\""), "{degraded}");
    assert!(degraded.ends_with("# EOF\n"), "{degraded}");

    router.shutdown();
    cluster.stop();
}

/// Graceful shutdown of the router's own listener: idle clients parked on
/// both protocols observe EOF instead of a hang, the accept thread joins
/// (no leaked listener threads), and the address stops serving.
#[test]
fn router_listener_graceful_shutdown_drains_and_releases() {
    use std::io::{Read, Write};

    let store = regular_store(40, 8, 29);
    let cluster = Cluster::start(store.as_ref(), ShardStrategy::Range, 2, 1, "shutdown");
    let (state, listener, addr) =
        word2ket::cluster::server::spawn(cluster.topo.clone(), router_cfg(), "127.0.0.1:0")
            .unwrap();
    let st = state.clone();
    let accept = std::thread::spawn(move || word2ket::cluster::server::accept_loop(listener, st));

    // One served request per protocol, then the clients sit idle — no QUIT.
    let mut bin = BinaryClient::connect(&addr).unwrap();
    assert_eq!(bin.lookup(&[3]).unwrap()[0], store.lookup(3));
    let mut text = std::net::TcpStream::connect(&addr).unwrap();
    text.write_all(b"PING\n").unwrap();
    let mut ok = [0u8; 3];
    text.read_exact(&mut ok).unwrap();
    assert_eq!(&ok, b"OK\n");

    state.shutdown();
    accept.join().expect("accept loop must exit after shutdown");

    // The parked text client is unblocked with EOF/reset, never a hang.
    text.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut probe = [0u8; 1];
    match text.read(&mut probe) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("expected EOF after shutdown, read {n} bytes"),
    }

    // A fresh client finds nobody serving on the old address (connection
    // refused, or an accepted-then-reset socket that cannot complete a
    // round-trip).
    match BinaryClient::connect(&addr) {
        Ok(mut c) => assert!(c.ping().is_err(), "listener still serving after shutdown"),
        Err(_) => {}
    }

    cluster.stop();
}
